"""The DAG list-scheduler: schedule search over the trace dependency DAG.

Properties the search must uphold (the satellite suite of the schedule
search PR):

1. the scheduler's output is never predicted slower than the in-order
   recorded trace (every rewrite — merge, Valiant attr rewrite, overlap
   group, hoist — is cost-gated);
2. ``simulate_program`` equivalence holds under *arbitrary legal
   reorderings*: any topological order of the must-precede DAG executes
   bit-identically, and the searched schedule of a reordered recording
   still matches eager execution of the original;
3. reordered-but-equivalent traces canonicalize to one
   ``program_signature`` and therefore share one ``ProgramCache``
   entry, whose cached program materializes correctly against either
   recording.

Targeted tests pin the behaviours the adjacent-only peephole could not
reach: non-adjacent merges, non-adjacent overlap hoists, the
Valiant-aware attr rewrite, and ``SuperstepProgram.explain``.  The
fast-tier guard at the bottom prices the canned benchmark traces
(``benchmarks/schedule_search.py``) on the DCN model and fails if any
optimized predicted cost regresses past its recorded bound.
"""

import numpy as np
import pytest

from repro.core import (LPF_SYNC_DEFAULT, Msg, ProgramCache, ProgramStep,
                        Slot, SyncAttributes, canonical_order,
                        optimize_program, plan_sync, program_signature,
                        simulate_program)
from repro.core.machine import CPU_HOST, probe
from repro.core.program import _must_precede, trace_slot_map

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.fast

MACHINE = probe({"x": 8}, CPU_HOST)


def table_property(fn):
    if HAVE_HYPOTHESIS:
        return settings(deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(60))(fn)


def make_slot(sid, size, dtype="int32", kind="global"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind=kind, orig_shape=(size,))


def random_program(seed):
    """Random legal trace; slot sizes are pairwise distinct so step
    content keys referencing fresh slots are unambiguous (identical-key
    ties are then only between truly interchangeable steps, keeping the
    reorder-invariant-signature property exact)."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 8))
    n_slots = int(rng.integers(2, 5))
    sizes = rng.choice(np.arange(8, 40), size=n_slots, replace=False)
    slots = [make_slot(100 + i, int(sizes[i])) for i in range(n_slots)]
    steps = []
    for k in range(int(rng.integers(2, 7))):
        reduce_op = [None, None, None, "sum", "max", "min"][
            int(rng.integers(6))]
        attrs = SyncAttributes(
            method=["auto", "direct"][int(rng.integers(2))],
            reduce_op=reduce_op)
        msgs = []
        for _ in range(int(rng.integers(0, 9))):
            a = slots[int(rng.integers(len(slots)))]
            b = slots[int(rng.integers(len(slots)))]
            size = int(rng.integers(1, min(a.size, b.size) + 1))
            msgs.append(Msg(
                src=int(rng.integers(p)), dst=int(rng.integers(p)),
                src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
                dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
                size=size))
        steps.append(ProgramStep(tuple(msgs), attrs, f"s{k}"))
    return p, slots, steps


def initial_values(slots, p, seed):
    rng = np.random.default_rng(seed + 1)
    return {s.sid: rng.integers(-10_000, 10_000,
                                size=(p, s.size)).astype(np.int32)
            for s in slots}


def legal_reordering(steps, seed):
    """A random topological order of the trace's must-precede DAG —
    an *arbitrary legal reordering* of the recording."""
    rng = np.random.default_rng(seed + 13)
    n = len(steps)
    npreds = [0] * n
    succs = [[] for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if _must_precede(steps[i], steps[j]):
                succs[i].append(j)
                npreds[j] += 1
    ready = [i for i in range(n) if npreds[i] == 0]
    perm = []
    while ready:
        k = ready.pop(int(rng.integers(len(ready))))
        perm.append(k)
        for j in succs[k]:
            npreds[j] -= 1
            if npreds[j] == 0:
                ready.append(j)
    return perm


# ---------------------------------------------------------------------------
# (1) the searched schedule is never predicted slower than in-order
# ---------------------------------------------------------------------------

@table_property
def test_search_never_slower_than_in_order(seed):
    """Every rewrite is cost-gated, so the searched schedule's
    predicted BSP time (overlap pricing included) never exceeds the
    recorded trace's.  (Against the *peephole* the greedy search wins
    on the canned traces — enforced below and in the benchmark — but
    carries no blanket guarantee: different group boundaries can
    occasionally trade.)"""
    p, slots, steps = random_program(seed)
    prog = optimize_program(steps, p, MACHINE)
    raw = sum(
        plan_sync(list(s.msgs), p, s.attrs).cost.predicted_seconds(MACHINE)
        for s in steps)
    assert prog.predicted_seconds(MACHINE) <= raw + 1e-15
    assert abs(prog.in_order_seconds(MACHINE) - raw) < 1e-15
    # the peephole obeys the same in-order bound
    peephole = optimize_program(steps, p, MACHINE, search=False)
    assert peephole.predicted_seconds(MACHINE) <= raw + 1e-15


# ---------------------------------------------------------------------------
# (2) equivalence under arbitrary legal reorderings
# ---------------------------------------------------------------------------

@table_property
def test_legal_reordering_preserves_semantics(seed):
    """Any topological order of the must-precede DAG — the space the
    list-scheduler searches — executes bit-identically to the recorded
    order, and the searched schedule of the *reordered* recording still
    matches eager execution of the original."""
    p, slots, steps = random_program(seed)
    perm = legal_reordering(steps, seed)
    reordered = [steps[i] for i in perm]
    values = initial_values(slots, p, seed)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    shuffled = simulate_program([(s.msgs, s.attrs) for s in reordered],
                                values)
    for sid in eager:
        assert (eager[sid] == shuffled[sid]).all(), sid
    prog = optimize_program(reordered, p, MACHINE)
    tables = [(m, a) for m, a, _, _
              in prog.materialize(trace_slot_map(reordered))]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid


# ---------------------------------------------------------------------------
# (3) reordered-equivalent traces share one ProgramCache signature
# ---------------------------------------------------------------------------

@table_property
def test_reordered_traces_share_signature_and_cache(seed):
    p, slots, steps = random_program(seed)
    perm = legal_reordering(steps, seed)
    reordered = [steps[i] for i in perm]
    assert program_signature(steps, p) == program_signature(reordered, p)
    # one cache entry serves both recordings, and the shared program
    # materializes correctly against the reordered trace
    cache = ProgramCache()
    prog1 = cache.get_or_build(steps, p, MACHINE)
    prog2 = cache.get_or_build(reordered, p, MACHINE)
    assert prog1 is prog2
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    values = initial_values(slots, p, seed)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    tables = [(m, a) for m, a, _, _ in prog2.materialize(reordered)]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid


def test_canonical_order_is_reorder_invariant():
    """The bucketed DDP shape interleaved two ways canonicalizes to one
    sequence (content-keyed ready selection, not recorded position)."""
    p = 4
    from benchmarks.schedule_search import canned_bucketed_trace
    _, _, steps, _ = canned_bucketed_trace(p=p, n_buckets=2)
    rs0, ag0, rs1, ag1 = steps
    a = [rs0, ag0, rs1, ag1]
    b = [rs0, rs1, ag1, ag0]          # a legal interleaving
    ca = [a[i] for i in canonical_order(a)]
    cb = [b[i] for i in canonical_order(b)]
    assert [s.label for s in ca] == [s.label for s in cb]
    assert program_signature(a, p) == program_signature(b, p)


def test_canonical_order_breaks_structural_ties():
    """Symmetric steps 1-WL colour refinement cannot separate must still
    share one cache entry (the C6 + 2xC3 counterexample).

    Twelve bit-identical read-only steps whose slot-*sharing* graph is a
    6-cycle plus two triangles: every step reads two ring slots and
    writes its own private slot, so there are no precedence edges, every
    content key is equal, and WL refinement colours all twelve steps
    identically (2-regular, identical neighbourhoods at every round) —
    yet a C6 step and a C3 step are NOT interchangeable.  The old
    tie-break fell back to recorded position, so two recordings of the
    same program could canonicalize differently and miss each other's
    ProgramCache entry; the canonical-form comparison must map every
    shuffle to one signature: exactly 1 miss, then all hits."""
    p = 4
    rng = np.random.default_rng(5)
    ring = [make_slot(i, 8) for i in range(12)]

    def step(i, a, b):
        w = make_slot(100 + i, 8)
        return ProgramStep((Msg(0, 1, ring[a], 0, w, 0, 4),
                            Msg(2, 3, ring[b], 0, w, 4, 4)),
                           LPF_SYNC_DEFAULT, "t")
    # C6 over ring[0:6], two C3s over ring[6:9] and ring[9:12]
    steps = [step(i, i, (i + 1) % 6) for i in range(6)]
    steps += [step(6 + i, 6 + i, 6 + (i + 1) % 3) for i in range(3)]
    steps += [step(9 + i, 9 + i, 9 + (i + 1) % 3) for i in range(3)]

    sig = program_signature(steps, p)
    cache = ProgramCache()
    cache.get_or_build(steps, p, MACHINE)
    for _ in range(6):
        shuffled = [steps[i] for i in rng.permutation(len(steps))]
        assert program_signature(shuffled, p) == sig
        cache.get_or_build(shuffled, p, MACHINE)
    assert cache.stats.misses == 1 and cache.stats.hits == 6
    assert len(cache) == 1


# ---------------------------------------------------------------------------
# targeted: what the adjacent-only peephole could not find
# ---------------------------------------------------------------------------

def test_non_adjacent_merge_over_blocker():
    """[A, X, B]: A and B are equal-attrs independent shifts, X is a
    reduce superstep between them.  Adjacent-only batching cannot merge
    A+B (X differs in attrs); the list-scheduler hoists B over X."""
    p = 4
    A, B, C = make_slot(1, 16), make_slot(2, 16), make_slot(3, 16)
    s_a = ProgramStep((Msg(0, 1, A, 0, B, 0, 4),), LPF_SYNC_DEFAULT, "a")
    s_x = ProgramStep((Msg(2, 0, A, 8, C, 0, 4),),
                      SyncAttributes(reduce_op="sum"), "x")
    s_b = ProgramStep((Msg(2, 3, A, 4, B, 4, 4),), LPF_SYNC_DEFAULT, "b")
    searched = optimize_program([s_a, s_x, s_b], p, MACHINE)
    peephole = optimize_program([s_a, s_x, s_b], p, MACHINE, search=False)
    assert peephole.n_merged == 0
    assert searched.n_merged == 1
    merged = next(s for s in searched.steps if len(s.merged_from) > 1)
    assert merged.label == "a+b"
    assert searched.n_hoisted >= 1
    assert searched.predicted_seconds(MACHINE) < \
        peephole.predicted_seconds(MACHINE)
    # semantics preserved
    values = initial_values([A, B, C], p, 3)
    eager = simulate_program([(s.msgs, s.attrs)
                              for s in (s_a, s_x, s_b)], values)
    tables = [(m, at) for m, at, _, _ in searched.materialize(
        trace_slot_map([s_a, s_x, s_b]))]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all()


def test_non_adjacent_overlap_hoist():
    """[A, X, B]: X depends on A, B is independent of both and fat.
    The peephole's best is [A][X || B]; the search hoists B next to A —
    [A || B][X] — hiding the fat superstep under the other fat one."""
    p = 4
    w = 64
    SA, DA = make_slot(1, p * w), make_slot(2, p * w)
    SB, DB = make_slot(3, p * w), make_slot(4, p * w)
    XD = make_slot(5, 16)
    big_a = tuple(Msg(s, d, SA, d * w, DA, s * w, w)
                  for s in range(p) for d in range(p))
    big_b = tuple(Msg(s, d, SB, d * w, DB, s * w, w)
                  for s in range(p) for d in range(p))
    thin_x = (Msg(1, 2, DA, 0, XD, 0, 4),)       # reads A's output
    s_a = ProgramStep(big_a, LPF_SYNC_DEFAULT, "A")
    s_x = ProgramStep(thin_x, LPF_SYNC_DEFAULT, "X")
    s_b = ProgramStep(big_b, LPF_SYNC_DEFAULT, "B")
    searched = optimize_program([s_a, s_x, s_b], p, MACHINE)
    peephole = optimize_program([s_a, s_x, s_b], p, MACHINE, search=False)
    assert peephole.overlap_groups == ((0,), (1, 2),)
    # searched: A || B first (B hoisted over X), then X
    assert len(searched.groups()[0]) == 2
    labels = {searched.steps[i].label for i in searched.groups()[0]}
    assert labels == {"A", "B"}
    assert searched.n_hoisted >= 1
    assert searched.predicted_seconds(MACHINE) < \
        peephole.predicted_seconds(MACHINE)


def test_valiant_aware_rewrite_fires_and_is_exact():
    """The fragmented fat relation (WAR-coupled, so overlap is
    inadmissible): each 16-round direct superstep is rewritten to
    two-phase Valiant routing; the rewrite must be recorded,
    cost-improving, and bit-exact."""
    from benchmarks.schedule_search import (DCN, canned_fragmented_trace)
    p, slots, steps, scratch = canned_fragmented_trace()
    prog = optimize_program(steps, p, DCN, scratch=scratch)
    assert prog.n_rewritten == 2
    for st in prog.steps:
        assert st.rewrite == "valiant"
        assert st.attrs.method == "valiant"
        assert st.plan.method == "valiant"
        assert not st.unchanged
    assert prog.overlap_groups == ((0,), (1,))    # WAR: no overlap
    assert prog.predicted_seconds(DCN) < prog.in_order_seconds(DCN)
    # without a scratch slot the rewrite is inadmissible
    no_scratch = optimize_program(steps, p, DCN)
    assert no_scratch.n_rewritten == 0
    # semantics: simulate ignores the execution method — the rewrite is
    # only legal because the tables are conflict-free
    values = initial_values(slots, p, 5)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    tables = [(m, a) for m, a, _, _
              in prog.materialize(trace_slot_map(steps))]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all()


def test_merged_valiant_rewrite():
    """When two fragmented supersteps share their slot-pair space (the
    merged table consolidates through few scratch groups) and a WAR
    coupling forbids overlap, the scheduler merges them AND rewrites
    the merged fat superstep to Valiant — the combined move of the
    merge gate and the attr rewrite."""
    p = 8
    A = [make_slot(300 + i, 32) for i in range(4)]
    B = [make_slot(310 + i, 32) for i in range(4)]
    C, scratch = make_slot(320, 32), make_slot(399, 4096)
    msgs1, msgs2 = [], []
    k = 0
    for a in A:
        for b in B:
            m = Msg((k * 3) % p, (k * 5 + 1) % p, a, (k * 2) % 16,
                    b, (k * 3) % 16, 4)
            (msgs1 if k % 2 == 0 else msgs2).append(m)
            k += 1
    # WAR coupling: frag2 writes the exact range frag1's first message
    # reads — overlap (commutation) is out, merging is still legal
    m0 = msgs1[0]
    msgs2.append(Msg(6, m0.src, C, 0, m0.src_slot, m0.src_off, m0.size))
    steps = [ProgramStep(tuple(msgs1), LPF_SYNC_DEFAULT, "frag1"),
             ProgramStep(tuple(msgs2), LPF_SYNC_DEFAULT, "frag2")]
    from benchmarks.schedule_search import DCN
    prog = optimize_program(steps, p, DCN, scratch=scratch)
    assert prog.n_merged == 1 and len(prog.steps) == 1
    assert prog.steps[0].rewrite == "valiant"
    assert prog.steps[0].merged_from == (0, 1)
    assert prog.predicted_seconds(DCN) < prog.in_order_seconds(DCN)
    values = initial_values(A + B + [C], p, 11)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    tables = [(m, a) for m, a, _, _
              in prog.materialize(trace_slot_map(steps))]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all()


def test_valiant_rewrite_refused_on_conflicting_writes():
    """A method rewrite must never change CRCW winners: tables with
    overlapping destination writes keep their recorded method."""
    p = 8
    A, B = make_slot(1, 64), make_slot(2, 64)
    scratch = make_slot(99, 4096)
    # many messages all landing on the same destination range: heavily
    # round-coloured (rewrite-tempting) but arbitration-ordered
    msgs = tuple(Msg(s, 0, A, s * 4, B, 0, 4) for s in range(1, p))
    steps = [ProgramStep(msgs, LPF_SYNC_DEFAULT, "hot1"),
             ProgramStep(msgs, LPF_SYNC_DEFAULT, "hot2")]
    prog = optimize_program(steps, p, MACHINE, scratch=scratch)
    assert prog.n_rewritten == 0
    assert all(s.attrs.method != "valiant" for s in prog.steps)


def test_peephole_program_materializes_in_recorded_order():
    """A ``search=False`` program assigns ranks and canonical slot
    indices in RECORDED order; ``materialize`` must resolve them the
    same way even when the trace's canonical order differs (regression:
    ranks used to be resolved through canonical_order unconditionally,
    rebinding the wrong slots/labels)."""
    p = 4
    A, B, C = make_slot(1, 16), make_slot(2, 16), make_slot(3, 24)
    # canonical order sorts the reduce step differently than recorded,
    # and the distinct source slots make the two slot maps differ
    s_zz = ProgramStep((Msg(0, 1, A, 0, B, 0, 4),),
                       SyncAttributes(reduce_op="sum"), "zz")
    s_aa = ProgramStep((Msg(2, 3, C, 8, B, 8, 4),), LPF_SYNC_DEFAULT,
                       "aa")
    steps = [s_zz, s_aa]
    assert canonical_order(steps) == [1, 0]      # the interesting case
    prog = optimize_program(steps, p, MACHINE, search=False)
    assert not prog.canonical
    values = initial_values([A, B, C], p, 9)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    tables = [(m, a) for m, a, _, _ in prog.materialize(steps)]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid
    # the pre-computed slot-list path must honour the program's order
    # too: prog.slot_map uses recorded order for peephole programs
    tables2 = [(m, a) for m, a, _, _
               in prog.materialize(prog.slot_map(steps))]
    opt2 = simulate_program(tables2, values)
    for sid in eager:
        assert (eager[sid] == opt2[sid]).all(), sid
    # labels resolve against recorded positions too
    ents = prog.materialize(steps, labels=["zz", "aa"])
    assert sorted(e[2] for e in ents) == ["aa", "zz"]


def test_explain_renders_schedule():
    from benchmarks.schedule_search import DCN, canned_bucketed_trace
    p, _, steps, _ = canned_bucketed_trace(p=4, n_buckets=2)
    prog = optimize_program(steps, p, DCN)
    text = prog.explain(DCN)
    assert "issue groups" in text
    assert "non-adjacent hoists" in text
    assert "b0.rs || b1.rs" in text
    assert "in-order BSP time" in text and "x)" in text
    # without a machine the rendering still works (no cost comparison)
    assert "in-order BSP time" not in prog.explain()


# ---------------------------------------------------------------------------
# XLA: searched schedules on a real mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_valiant_rewrite_executes_on_mesh(mesh8):
    """A recorded fragmented WAR-coupled trace must (a) take the
    Valiant attr rewrite at flush time, (b) lower and execute through
    the two-phase routing, producing values bit-identical to eager
    per-superstep sync, and (c) ledger the rewritten method."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.core import compat

    boxes = {}

    def run(recorded):
        def wrapped(_):
            ctx = lpf.LPFContext(("x",))
            boxes[recorded] = ctx
            p = ctx.p
            ctx.resize_message_queue(40, valiant_payload=1024,
                                     payload_dtype=jnp.int32)
            ctx.resize_memory_register(12)   # + the valiant scratch
            A = [ctx.register_global(
                f"A{i}", (jnp.arange(32) + 100 * ctx.pid + i).astype(
                    jnp.int32)) for i in range(4)]
            B = [ctx.register_global(f"B{i}", jnp.zeros(32, jnp.int32))
                 for i in range(4)]
            C = [ctx.register_global(
                f"C{i}", (jnp.arange(32) * 2 - ctx.pid + i).astype(
                    jnp.int32)) for i in range(4)]
            msgs1, msgs2 = [], []
            for ai in range(4):
                for bi in range(4):
                    k = 4 * ai + bi
                    src = (k * 3) % p
                    msgs1.append((src, (k * 5 + 1) % p, A[ai], 8 * bi,
                                  B[bi], (k * 3) % 16, 4))
                    msgs2.append(((k * 7 + 2) % p, src, C[bi], 8 * ai,
                                  A[ai], 8 * bi, 4))

            def steps():
                ctx.put_msgs(msgs1)
                ctx.sync(label="frag1")
                ctx.put_msgs(msgs2)
                ctx.sync(label="frag2")

            if recorded:
                with ctx.program():
                    steps()
            else:
                steps()
            return tuple(ctx.value(s) for s in A + B)

        fn = jax.jit(compat.shard_map(
            wrapped, mesh=mesh8, in_specs=(P(),),
            out_specs=tuple(P("x") for _ in range(8)), check_vma=False))
        return [np.asarray(v) for v in fn(jnp.zeros(1))]

    eager = run(False)
    searched = run(True)
    for e, s in zip(eager, searched):
        np.testing.assert_array_equal(e, s)
    prog = boxes[True].last_program
    assert prog.n_rewritten >= 1
    records = boxes[True].ledger.records
    assert any(r.method == "valiant" for r in records), records
    # every ledger entry equals its plan's cost (label aside)
    import dataclasses
    for rec, st in zip(records, prog.steps):
        assert dataclasses.replace(st.plan.cost, label=rec.label) == rec


@pytest.mark.slow
def test_reordered_recordings_share_cache_on_mesh(mesh8):
    """Recording the same two independent shifts in either order must
    hit one ProgramCache entry on the real ``ctx.program()`` path (the
    canonical signature is reorder-invariant), with correct values and
    labels either way."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.core import compat

    plan_cache = lpf.PlanCache()
    program_cache = lpf.ProgramCache()
    boxes = {}

    def spmd(ctx, swap):
        p = ctx.p
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * p)
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))

        def shift1():
            ctx.put(a, b, to=lambda s: (s + 1) % p, size=4)
            ctx.sync(label="shift1")

        def shift2():
            ctx.put(a, b, to=lambda s: (s + 2) % p, dst_off=4, size=4)
            ctx.sync(label="shift2")

        with ctx.program():
            if swap:
                shift2()
                shift1()
            else:
                shift1()
                shift2()
        return ctx.value(b)

    for swap in (False, True):
        def wrapped(_, swap=swap):
            ctx = lpf.LPFContext(("x",), plan_cache=plan_cache,
                                 program_cache=program_cache)
            boxes[swap] = ctx
            return spmd(ctx, swap)

        fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8,
                                      in_specs=(P(),), out_specs=P("x"),
                                      check_vma=False))
        out = np.asarray(fn(jnp.zeros(1))).reshape(8, 8)
        for d in range(8):
            np.testing.assert_allclose(out[d, :4],
                                       np.arange(4.0) + (d - 1) % 8)
            np.testing.assert_allclose(out[d, 4:],
                                       np.arange(4.0) + (d - 2) % 8)
    # the swapped recording replays the cached program of the first
    assert program_cache.stats.misses == 1
    assert program_cache.stats.hits == 1
    assert boxes[False].last_program is boxes[True].last_program


# ---------------------------------------------------------------------------
# fast-tier guard: canned traces must not regress past their bounds
# ---------------------------------------------------------------------------

def test_canned_trace_costs_within_guard_bounds():
    """The canned benchmark traces' searched DCN-model costs are the
    PR's enforceable perf claim: fail when any optimized predicted cost
    regresses past its recorded bound, stops beating the peephole, or
    stops finding a non-adjacent/rewrite opportunity."""
    from benchmarks.schedule_search import (CANNED, DCN, GUARD_BOUNDS_US,
                                            run_canned)
    for name in CANNED:
        searched, peephole, _, _ = run_canned(name)
        s_us = searched.predicted_seconds(DCN) * 1e6
        assert s_us <= GUARD_BOUNDS_US[name], \
            f"{name}: {s_us:.1f}us > guard {GUARD_BOUNDS_US[name]}us"
        assert s_us < peephole.predicted_seconds(DCN) * 1e6, name
        assert searched.n_hoisted + searched.n_rewritten >= 1, name


# ---------------------------------------------------------------------------
# canonical-order tie-break: bit-identical symmetric steps
# ---------------------------------------------------------------------------

def test_canonical_tie_break_is_structural():
    """Two content-identical steps (same msg tuples over fresh slots of
    the same shape) tie on the content key; the winner must be chosen
    structurally (conflict-DAG + slot-sharing refinement), not by
    recorded position — otherwise the two interleavings below number
    the canonical slots differently and one program costs two
    ProgramCache entries."""
    p = 4
    a1, b1 = make_slot(201, 16), make_slot(202, 16)
    a2, b2 = make_slot(203, 16), make_slot(204, 16)
    cs = make_slot(205, 8)
    attrs = SyncAttributes()

    def shift(src, dst):
        return ProgramStep(tuple(Msg(s, (s + 1) % p, src, 0, dst, 0, 16)
                                 for s in range(p)), attrs, "shift")

    A = shift(a1, b1)                 # bit-identical content to B ...
    B = shift(a2, b2)
    # ... but C reads A's output — the conflict edge distinguishes them
    C = ProgramStep(tuple(Msg(s, (s + 1) % p, b1, 0, cs, 0, 8)
                          for s in range(p)), attrs, "use")
    rec1 = [A, B, C]
    rec2 = [B, A, C]                  # a legal reordering (A,B commute)

    ca = canonical_order(rec1)
    cb = canonical_order(rec2)
    assert [rec1[i].label for i in ca] == [rec2[i].label for i in cb]
    assert program_signature(rec1, p) == program_signature(rec2, p)

    cache = ProgramCache()
    p1 = cache.get_or_build(rec1, p, MACHINE)
    p2 = cache.get_or_build(rec2, p, MACHINE)
    assert p1 is p2
    assert cache.stats.misses == 1 and cache.stats.hits == 1

    # the shared program still executes both recordings correctly
    slots = [a1, b1, a2, b2, cs]
    values = initial_values(slots, p, 7)
    eager = simulate_program([(s.msgs, s.attrs) for s in rec1], values)
    tables = [(m, a) for m, a, _, _ in p2.materialize(rec2)]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid


def test_program_cache_lru_semantics():
    """Hits refresh recency (move_to_end) and eviction counts match:
    insert maxsize+2 distinct programs while touching the first — the
    hot entry survives, the two coldest leave, and any compiled
    artifact leaves with its program."""
    cache = ProgramCache(maxsize=4)
    progs = []
    for k in range(6):
        # distinct signatures: one step shifting a distinctly-sized slot
        src = make_slot(300 + 2 * k, 8 + k)
        dst = make_slot(301 + 2 * k, 8 + k)
        steps = [ProgramStep(
            (Msg(0, 1, src, 0, dst, 0, 8 + k),), SyncAttributes(), "s")]
        if k in (2, 4):
            # touch the hot entry (k=0) between inserts — at k=4 the
            # cache is full and k=0 is oldest; without move_to_end the
            # next two inserts would evict it
            assert cache.get_or_build(progs[0], 4, MACHINE) is not None
        prog, key = cache.get_or_build_keyed(steps, 4, MACHINE)
        assert cache.certify(key, steps).ok
        cache.set_compiled(key, ("x",), object())
        progs.append(steps)
    assert cache.stats.evictions == 2
    # the hot entry survived 6 inserts into maxsize=4 ...
    before = cache.stats.misses
    cache.get_or_build(progs[0], 4, MACHINE)
    assert cache.stats.misses == before          # hit, not rebuild
    # ... and the evicted programs took their compiled artifacts along
    # (and their verifier certificates)
    assert len(cache._compiled) == len(cache._programs) == 4
    assert len(cache._certs) == 4
