"""The hardened continuous-batching serve loop.

Three layers under test:
  * the admission controller — the model-priced deadline bound is a
    theorem on the virtual model clock (admitted => predicted
    completion <= deadline, completed => completion <= prediction, so
    zero deadline misses fault-free), every refusal classified;
  * the degradation ladder — bounded queue backpressure, shrink
    routing under load, priority/deadline shedding, decode fallback
    with bucket quarantine, graceful drain;
  * the pure-LPF decode engine — requests decode bit-identical solo,
    batched, and on the per-token fallback path, and the admission
    price equals the executed ledger (model compliance end to end).

The fast tier runs the server against a deterministic fake engine (no
devices); the slow tier runs the real ``ProgramDecodeEngine`` on the
host mesh.
"""

import random

import pytest

from repro.core import LPFFatalError, ProgramCache
from repro.core.program import SuperstepProgram
from repro.runtime import faults
from repro.runtime.faults import FaultPlan
from repro.runtime.server import (REASONS, LPFServer, ServeOutcome,
                                  ServeRejected, ServeRequest,
                                  synthetic_requests)

pytestmark = pytest.mark.fast


# ==========================================================================
# fixtures: a deterministic engine with no devices behind it
# ==========================================================================

class FakeEngine:
    """Protocol-complete decode engine: tokens are a pure function of
    (seed, position), service is priced at a flat per-token cost, and
    failures are scripted via ``fail_with``."""

    def __init__(self, buckets=((2, 8), (4, 8)), token_s=1e-3):
        self._buckets = tuple(tuple(b) for b in buckets)
        self.token_s = token_s
        self.quarantined = set()
        self.decodes = 0
        self.flushed = 0
        self.fail_with = []        # exceptions raised by upcoming decodes

    def buckets(self):
        return self._buckets

    def token_seconds(self, bucket):
        return self.token_s

    def overhead_seconds(self, bucket):
        return 0.0

    def round_tokens(self, bucket, n):
        t = 1
        while t < n:
            t *= 2
        return min(t, bucket[1])

    def ledger_seconds(self, bucket, n_tokens):
        return self.token_s * n_tokens

    def quarantine(self, bucket):
        self.quarantined.add(tuple(bucket))

    def flush(self):
        self.flushed += 1
        return 0

    def decode(self, bucket, reqs, n_tokens):
        self.decodes += 1
        if self.fail_with:
            raise self.fail_with.pop(0)
        return {r.rid: tuple((r.seed * 31 + i) % 997
                             for i in range(n_tokens)) for r in reqs}


def req(rid, n=4, deadline=10.0, priority=0, seed=None):
    return ServeRequest(rid=rid, n_tokens=n, deadline_s=deadline,
                        priority=priority,
                        seed=rid * 7919 if seed is None else seed)


def expected_tokens(r, n=None):
    return tuple((r.seed * 31 + i) % 997
                 for i in range(n if n is not None else r.n_tokens))


# ==========================================================================
# admission: the deadline bound is a theorem on the model clock
# ==========================================================================

@pytest.mark.parametrize("seed", range(8))
def test_admission_deadline_property(seed):
    """Seeded property test over random arrival patterns: admitted =>
    predicted <= deadline; completed => completion <= predicted (so 0
    deadline misses); refused => classified reason."""
    rng = random.Random(seed)
    eng = FakeEngine()
    srv = LPFServer(eng, max_queue=rng.choice([4, 8, 16]))
    reqs = synthetic_requests(40, seed, eng.buckets(),
                              token_cost_s=eng.token_s,
                              tight_frac=0.35)
    admitted = set()
    for r in reqs:
        out = srv.submit(r)
        if out.status == "admitted":
            admitted.add(r.rid)
            assert out.predicted_v <= out.deadline_v
        else:
            assert out.reason in REASONS
            assert isinstance(out.error, ServeRejected)
        if rng.random() < 0.4:
            srv.step()
    srv.run_until_idle()
    outs = srv.take_outcomes()
    assert set(outs) == {r.rid for r in reqs}
    assert srv.metrics.deadline_misses == 0
    for r in reqs:
        out = outs[r.rid]
        if out.status == "completed":
            assert r.rid in admitted
            assert out.completion_v <= out.predicted_v + 1e-12
            assert out.completion_v <= out.deadline_v + 1e-12
            assert out.tokens == expected_tokens(r)
        else:
            assert out.classified, (r.rid, out.status, out.reason)
    # fault-free, every admitted request terminates: completed, or
    # shed under overload with the classified reason (never silently
    # lost, never a deadline miss)
    done = {rid for rid, o in outs.items() if o.status == "completed"}
    shed = {rid for rid, o in outs.items() if o.status == "shed"}
    assert done <= admitted
    assert done | shed >= admitted


def test_admission_accounts_backlog():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=8)
    # each request costs 8 * 1e-3; deadline fits one but not a queue
    assert srv.submit(req(0, n=8, deadline=0.009)).status == "admitted"
    out = srv.submit(req(1, n=8, deadline=0.009))
    assert out.status == "rejected" and out.reason == "deadline_unmeetable"
    # a deadline with room for the backlog is admitted
    assert srv.submit(req(2, n=8, deadline=0.025)).status == "admitted"


def test_rejection_classification():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=4)
    assert srv.submit(req(0, n=0)).reason == "no_bucket"
    assert srv.submit(req(1, n=64)).reason == "no_bucket"
    assert srv.submit(req(2, n=4, deadline=1e-9)
                      ).reason == "deadline_unmeetable"
    for out in srv.take_outcomes().values():
        assert out.classified


def test_backpressure_queue_full():
    eng = FakeEngine(buckets=((2, 8),))
    # shrink/shed disabled: the bounded queue itself must refuse
    srv = LPFServer(eng, max_queue=3, shrink_frac=1.0, shed_frac=1.0)
    for i in range(3):
        assert srv.submit(req(i)).status == "admitted"
    out = srv.submit(req(3))
    assert out.status == "rejected" and out.reason == "queue_full"
    srv.step()
    assert srv.submit(req(4)).status == "admitted"


def test_backlog_bound_rejects():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=64, reject_backlog_s=0.010)
    assert srv.submit(req(0, n=8, deadline=10.0)).status == "admitted"
    out = srv.submit(req(1, n=8, deadline=10.0))
    assert out.status == "rejected" and out.reason == "overloaded"


# ==========================================================================
# the degradation ladder
# ==========================================================================

def test_shrink_routes_to_small_bucket():
    eng = FakeEngine(buckets=((2, 8), (4, 8)))
    srv = LPFServer(eng, max_queue=8, shrink_frac=0.5)
    assert srv.submit(req(0)).bucket == (4, 8)       # level 0: throughput
    for i in range(1, 4):
        srv.submit(req(i))
    assert srv.level >= 1
    assert srv.submit(req(9)).bucket == (2, 8)       # level 1: latency
    srv.run_until_idle()


def test_shed_lowest_priority_latest_deadline():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=5, shrink_frac=0.2, shed_frac=0.4)
    # shed limit = int(0.4 * 5) = 2 queued tickets
    assert srv.submit(req(0, priority=1, deadline=5.0)
                      ).status == "admitted"
    assert srv.submit(req(1, priority=0, deadline=9.0)
                      ).status == "admitted"
    # a higher-priority arrival sheds rid 1 (lowest priority, latest
    # deadline) — classified, not silently dropped
    assert srv.submit(req(2, priority=2, deadline=5.0)
                      ).status == "admitted"
    shed = srv.outcomes[1]
    assert shed.status == "shed" and shed.reason == "shed_overload"
    assert shed.classified
    # an arrival that ranks below everything queued is itself refused
    out = srv.submit(req(3, priority=0, deadline=99.0))
    assert out.status == "rejected" and out.reason == "overloaded"
    srv.run_until_idle()
    assert srv.outcomes[0].status == "completed"
    assert srv.outcomes[2].status == "completed"


def test_continuous_batch_join_rule():
    """Members join the head-of-line leader's batch only if they do
    not extend its decode length; riders finish with the leader."""
    eng = FakeEngine(buckets=((4, 8),))
    srv = LPFServer(eng, max_queue=8)
    for r in (req(0, n=4), req(1, n=2), req(2, n=8), req(3, n=4)):
        assert srv.submit(r).status == "admitted"
    done = srv.step()      # leader rid0 (T=4) + riders rid1, rid3
    assert sorted(o.rid for o in done) == [0, 1, 3]
    assert all(o.status == "completed" for o in done)
    assert {o.rid: len(o.tokens) for o in done} == {0: 4, 1: 2, 3: 4}
    done = srv.step()      # rid2 decodes alone at T=8
    assert [o.rid for o in done] == [2]
    assert srv.metrics.batches == 2


# ==========================================================================
# decode failures: fallback, quarantine, classified batch failure
# ==========================================================================

def test_decode_fault_falls_back_and_quarantines():
    eng = FakeEngine(buckets=((2, 8),))
    eng.fail_with = [OSError("transient launch failure")]
    srv = LPFServer(eng, max_queue=4)
    srv.submit(req(0))
    done = srv.step()
    assert [o.status for o in done] == ["completed"]
    assert done[0].fallback and done[0].tokens == expected_tokens(req(0))
    assert (2, 8) in eng.quarantined
    assert srv.metrics.decode_fallbacks == 1
    assert srv.metrics.decode_failures == 0


def test_decode_fault_exhausted_fails_classified():
    eng = FakeEngine(buckets=((2, 8),))
    eng.fail_with = [OSError("boom"), OSError("boom again")]
    srv = LPFServer(eng, max_queue=4)
    srv.submit(req(0))
    srv.submit(req(1))
    done = srv.step()
    assert all(o.status == "rejected" and o.reason == "decode_failed"
               and o.classified for o in done)
    assert srv.metrics.decode_failures == 1
    # the server survives: the next batch serves normally
    srv.submit(req(2))
    assert srv.step()[0].status == "completed"


def test_fatal_lpf_error_not_degraded_around():
    eng = FakeEngine(buckets=((2, 8),))
    eng.fail_with = [LPFFatalError("contract violation")]
    srv = LPFServer(eng, max_queue=4)
    srv.submit(req(0))
    done = srv.step()
    assert done[0].reason == "decode_failed"
    # no fallback retry for a contract violation
    assert srv.metrics.decode_fallbacks == 0
    assert not eng.quarantined


def test_serve_fault_seams():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=4)
    with faults.inject(FaultPlan.parse("serve_admit@0")) as inj:
        out = srv.submit(req(0))
        assert out.status == "rejected" and out.reason == "admit_fault"
        assert out.classified
        assert inj.fired and inj.fired[0][0] == "serve_admit"
    with faults.inject(FaultPlan.parse("serve_decode@0")) as inj:
        srv.submit(req(1))
        done = srv.step()
        assert done[0].status == "completed" and done[0].fallback
    with faults.inject(FaultPlan.parse("serve_decode@0x-1")):
        srv.submit(req(2))
        done = srv.step()
        assert done[0].reason == "decode_failed" and done[0].classified


# ==========================================================================
# drain / health
# ==========================================================================

def test_graceful_drain():
    eng = FakeEngine(buckets=((2, 8),))
    srv = LPFServer(eng, max_queue=8)
    for i in range(5):
        srv.submit(req(i))
    health = srv.drain()
    assert health["draining"] and health["queue_depth"] == 0
    assert health["completed"] == 5          # in-flight work finished
    assert eng.flushed == 1                  # caches flushed
    out = srv.submit(req(9))                 # no new admissions
    assert out.status == "rejected" and out.reason == "draining"
    assert srv.drain()["queue_depth"] == 0   # idempotent


def test_health_snapshot_keys():
    eng = FakeEngine()
    srv = LPFServer(eng, max_queue=4)
    srv.submit(req(0, n=2, deadline=1e-9))
    srv.submit(req(1))
    srv.run_until_idle()
    h = srv.health()
    for key in ("vclock_s", "queue_depth", "backlog_s", "level",
                "submitted", "admitted", "completed", "rejected_total",
                "rejected_deadline_unmeetable", "deadline_misses",
                "batches", "tokens_decoded", "queue_peak",
                "stragglers_flagged"):
        assert key in h, key
    assert h["submitted"] == 2 and h["completed"] == 1


# ==========================================================================
# ProgramCache pinning (the hot-bucket protection satellite)
# ==========================================================================

def _one_step_trace(sid, size):
    import numpy as np
    from repro.core import Msg, ProgramStep, Slot
    a = Slot(sid=sid, name=f"a{sid}", size=size,
             dtype=np.dtype("float32"), kind="global",
             orig_shape=(size,))
    b = Slot(sid=sid + 1, name=f"b{sid}", size=size,
             dtype=np.dtype("float32"), kind="global",
             orig_shape=(size,))
    msgs = [Msg(s, (s + 1) % 4, a, 0, b, 0, size) for s in range(4)]
    return [ProgramStep(msgs=tuple(msgs), attrs=None, label=f"t{sid}")]


def _build_keyed(pc, plan_cache, machine, sid, size):
    from repro.core import LPF_SYNC_DEFAULT
    steps = _one_step_trace(sid, size)
    steps = [s.__class__(msgs=s.msgs, attrs=LPF_SYNC_DEFAULT,
                         label=s.label) for s in steps]
    return pc.get_or_build_keyed(steps, 4, machine,
                                 plan_cache=plan_cache)


def test_pinned_entries_survive_cold_burst():
    """Thousands of distinct one-shot signatures against a tiny
    maxsize: the pinned hot set must survive every eviction wave and
    the unpinned population must stay bounded."""
    from repro.core import LPFMachine, PlanCache
    machine = LPFMachine(p=4, g=1e-9, l=1e-6, r=1e-10)
    pc = ProgramCache(maxsize=8)
    plan_cache = PlanCache()
    hot = []
    for i in range(2):
        _prog, key = _build_keyed(pc, plan_cache, machine, 100 + 2 * i,
                                  10000 + i)
        pc.pin(key)
        hot.append(key)
    # distinct message sizes => distinct program signatures, no reuse
    for i in range(2000):
        _build_keyed(pc, plan_cache, machine, 1000 + 2 * i, 8 + i)
    for key in hot:
        assert key in pc.keys()              # never evicted
    assert len(pc) <= 8 + len(hot)           # maxsize bounds unpinned
    assert pc.stats.evictions >= 1990
    pc.unpin(hot[0])
    assert hot[0] not in pc.pinned
    with pytest.raises(LPFFatalError):
        pc.pin(("no", "such", "key"))


def test_pinning_is_observable_in_cache_metrics():
    import types
    from repro.core import CacheStats, LPFMachine, PlanCache
    from repro.runtime.monitor import cache_metrics
    machine = LPFMachine(p=4, g=1e-9, l=1e-6, r=1e-10)
    pc = ProgramCache(maxsize=4)
    _prog, key = _build_keyed(pc, PlanCache(), machine, 0, 8)
    pc.pin(key)
    ctx = types.SimpleNamespace(
        cache_stats={"plan": CacheStats(), "program": pc.stats},
        program_cache=pc)
    m = cache_metrics(ctx)
    assert m["program_pinned"] == 1
    assert m["program_entries"] == 1
    assert m["program_memory_only"] == 0
    assert "program_disk_errors" in m
    assert "program_compile_fallbacks" in m


# The real ProgramDecodeEngine (XLA-compiling) lives in the slow tier:
# tests/test_server_engine.py.
