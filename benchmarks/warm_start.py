"""Cross-process warm start from the persistent program cache.

The persistence claim of the program-cache PR, measured end to end: a
process records LPF programs with ``LPF_PROGRAM_CACHE_DIR`` set, exits,
and a *fresh* process replaying the same traces must

* re-plan nothing (plan-cache misses == 0),
* re-search nothing (program-cache misses == 0, every program a disk
  hit re-certified by the schedule verifier), and
* produce a ledger bit-for-bit identical to the recording process's —
  the warm start changes where the schedule comes from, never what is
  executed or charged.

Run as a parent (no ``--phase``) it spawns the two child processes
itself and asserts all three properties, then reports cold vs warm
trace-time wall clock.  The nightly CI job runs exactly this.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

P_AXIS = 8


def _workload(ctx, p):
    """Two recorded programs per run: a two-shift exchange and a
    scatter-style fan-out — distinct signatures, so a warm start must
    hit the store twice."""
    import jax.numpy as jnp

    ctx.resize_memory_register(3)
    ctx.resize_message_queue(2 * p)
    a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
    b = ctx.register_global("b", jnp.zeros(8))
    c = ctx.register_global("c", jnp.zeros(4))
    with ctx.program("shifts"):
        ctx.put(a, b, to=lambda s: (s + 1) % p, size=4)
        ctx.sync(label="shift1")
        ctx.put(a, b, to=lambda s: (s + 2) % p, dst_off=4, size=4)
        ctx.sync(label="shift2")
    with ctx.program("gather"):
        ctx.put(a, c, to=lambda s: (s + 3) % p, size=4)
        ctx.sync(label="shift3")
    return ctx.value(b) + ctx.value(c).sum()


def run_phase(out_path: str) -> dict:
    """One child process: trace + execute the workload, then dump the
    cache counters, the ledger, and the numeric result as JSON.  The
    persistent cache directory arrives via ``LPF_PROGRAM_CACHE_DIR`` —
    the environment contract a production worker would use."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import core as lpf
    from repro.core import compat, global_plan_cache, global_program_cache

    mesh = compat.make_mesh((P_AXIS,), ("x",))

    def spmd(ctx, s, p, _):
        return _workload(ctx, p)

    t0 = time.perf_counter()
    out, ledger = lpf.exec_(mesh, spmd, None, out_specs=P("x"),
                            return_ledger=True)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0

    prog_stats = global_program_cache().stats
    payload = {
        "wall_s": wall,
        "plan_misses": global_plan_cache().stats.misses,
        "program_misses": prog_stats.misses,
        "program_disk_hits": prog_stats.disk_hits,
        "program_disk_misses": prog_stats.disk_misses,
        "program_invalidated": prog_stats.invalidated,
        "ledger": [dataclasses.asdict(r) for r in ledger.records],
        "result": [float(v) for v in out.reshape(-1)],
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh)
    return payload


def _spawn(phase: str, cache_dir: str, out_path: str) -> dict:
    env = dict(os.environ,
               LPF_PROGRAM_CACHE_DIR=cache_dir,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    subprocess.run(
        [sys.executable, os.path.abspath(__file__),
         "--phase", phase, "--out", out_path],
        env=env, check=True, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    with open(out_path) as fh:
        return json.load(fh)


def main(csv: bool = True, cache_dir: str = None) -> list:
    tmp = None
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="lpf_warm_start_")
        cache_dir = tmp.name
    try:
        with tempfile.TemporaryDirectory() as outdir:
            cold = _spawn("record", cache_dir,
                          os.path.join(outdir, "cold.json"))
            warm = _spawn("replay", cache_dir,
                          os.path.join(outdir, "warm.json"))
    finally:
        if tmp is not None:
            tmp.cleanup()

    # the recording process must have actually searched and persisted
    assert cold["program_misses"] >= 2, cold
    assert cold["program_disk_hits"] == 0, cold
    # the fresh process: zero re-plans, zero re-searches, all disk hits
    assert warm["program_misses"] == 0, \
        f"warm start re-ran the schedule search: {warm}"
    assert warm["plan_misses"] == 0, \
        f"warm start re-planned a superstep: {warm}"
    assert warm["program_disk_hits"] >= 2, warm
    assert warm["program_invalidated"] == 0, warm
    # same schedule, same charge: ledger and numerics bit-for-bit
    assert warm["ledger"] == cold["ledger"], (cold["ledger"],
                                              warm["ledger"])
    assert warm["result"] == cold["result"]

    rows = [("warm_start", "cold", cold["program_misses"],
             cold["program_disk_hits"], f"{cold['wall_s'] * 1e3:.1f}"),
            ("warm_start", "warm", warm["program_misses"],
             warm["program_disk_hits"], f"{warm['wall_s'] * 1e3:.1f}")]
    if csv:
        print("bench,phase,search_misses,disk_hits,trace_ms")
        for row in rows:
            print(",".join(str(x) for x in row))
        print(f"# fresh-process replay: 0 re-plans, 0 searches, "
              f"{warm['program_disk_hits']} verified disk hits, ledger "
              f"bit-for-bit ({len(warm['ledger'])} records); trace time "
              f"{cold['wall_s'] / warm['wall_s']:.2f}x vs cold")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=["record", "replay"])
    ap.add_argument("--out")
    ap.add_argument("--cache-dir")
    args = ap.parse_args()
    if args.phase:
        stats = run_phase(args.out or os.path.join(
            tempfile.gettempdir(), f"warm_start_{args.phase}.json"))
        print(f"{args.phase}: {json.dumps({k: v for k, v in stats.items() if k not in ('ledger', 'result')})}")
    else:
        main(cache_dir=args.cache_dir)
