"""Program replay + bucketed gradient sync: the BSP case for fewer,
fatter h-relations.

Two measurements, both against the acceptance bars of the
SuperstepProgram PR:

1. **Bucketed grad sync** — an 8-layer gradient pytree synced across a
   q=8 pod axis three ways at *equal gradient bytes*: per-layer (one
   rs+ag pair per layer — the naive schedule), bucketed (4 layers per
   bucket -> supersteps / 4), and fully flattened (1 pair).  The ledger
   superstep count must drop >= 4x per-layer -> bucketed, and the
   executed ledger must equal the plan-time prediction bit-for-bit.

2. **Recorded-program replay** — a recorded 8-superstep program
   replayed N times at trace time, against eager per-superstep sync
   with (a) cold planning each iteration and (b) a warm plan cache.
   Replay pays one program-signature per iteration instead of one plan
   (or plan-signature) per superstep, and skips the optimizer after the
   first pass — the re-planning overhead the plan/cache/execute split
   still paid per superstep.
"""

from __future__ import annotations

import dataclasses
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.bsp.pod_sync import pod_allreduce
from repro.core import (CostLedger, LPF_SYNC_DEFAULT, Msg, PlanCache,
                        ProgramCache, ProgramStep, Slot, compat, plan_sync,
                        program_signature)
from repro.core.machine import CPU_HOST, probe


# --------------------------------------------------------------------------
# 1. bucketed gradient sync: superstep count at equal bytes
# --------------------------------------------------------------------------

LAYERS = 8
LAYER_ELEMS = 1 << 14          # 64 KiB per layer (f32)


def bench_bucketed(q: int = 8):
    mesh = compat.make_mesh((q,), ("x",))
    grads = {f"layer{i}": jnp.arange(LAYER_ELEMS, dtype=jnp.float32) + i
             for i in range(LAYERS)}
    specs = jax.tree.map(lambda _: P(), grads)
    layer_bytes = LAYER_ELEMS * 4
    rows = []
    for name, bucket in (("per-layer", 1),
                         ("bucketed", 4 * layer_bytes),
                         ("flat", None)):
        ledger = CostLedger()
        method = "bucketed" if bucket is not None else "rs+ag"

        def body(g):
            return pod_allreduce(g, q, "x", mean=True, ledger=ledger,
                                 method=method, bucket_bytes=bucket)

        fn = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                      out_specs=specs, check_vma=False))
        jax.block_until_ready(fn(grads))
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn(grads)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 10
        rows.append((name, ledger.supersteps, ledger.rounds,
                     ledger.wire_bytes, dt * 1e3))
    return rows


# --------------------------------------------------------------------------
# 2. recorded-program replay vs eager per-superstep planning
# --------------------------------------------------------------------------

N_STEPS = 8
N_ITERS = 200


def _make_slot(sid, size):
    return Slot(sid=sid, name=f"s{sid}", size=size,
                dtype=np.dtype("float32"), kind="global",
                orig_shape=(size,))


def _fresh_trace(p: int, it: int):
    """The same 8-superstep shift program staged through fresh slots
    each iteration — what a collective called in a loop produces."""
    steps = []
    for k in range(N_STEPS):
        a = _make_slot(10_000 * it + 2 * k, 64)
        b = _make_slot(10_000 * it + 2 * k + 1, 64)
        msgs = tuple(Msg(s, (s + k + 1) % p, a, 0, b, 0, 64, origin="put")
                     for s in range(p))
        steps.append(ProgramStep(msgs, LPF_SYNC_DEFAULT, f"s{k}"))
    return steps


def bench_replay(p: int = 8):
    machine = probe({"x": p}, CPU_HOST)
    rows = []

    # (a) eager, cold planner: plan every superstep every iteration
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        for st in _fresh_trace(p, it):
            plan_sync(list(st.msgs), p, st.attrs)
    rows.append(("eager-cold", N_ITERS * N_STEPS,
                 (time.perf_counter() - t0) * 1e3))

    # (b) eager, warm plan cache: one signature per superstep per iter
    cache = PlanCache()
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        for st in _fresh_trace(p, it):
            cache.get_or_plan(list(st.msgs), p, st.attrs)
    rows.append(("eager-warm", cache.stats.misses,
                 (time.perf_counter() - t0) * 1e3))

    # (c) recorded replay: one program signature per iteration; steps
    # the optimizer left untouched reuse their staged messages verbatim
    pcache = ProgramCache()
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        steps = _fresh_trace(p, it)
        prog = pcache.get_or_build(steps, p, machine)
        prog.materialize(steps)
    rows.append(("program-replay", pcache.stats.misses,
                 (time.perf_counter() - t0) * 1e3))
    return rows


def check_ledger_bit_for_bit(p: int = 8):
    """Executed ledger entries must equal the plans' predictions exactly
    (label aside) — run one recorded program on a real mesh and compare
    against from-scratch plans of its optimized tables."""
    mesh = compat.make_mesh((p,), ("x",))
    from repro import core as lpf

    def spmd(ctx, s, p_, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * p_)
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))
        with ctx.program():
            ctx.put(a, b, to=lambda s_: (s_ + 1) % p_, size=4)
            ctx.sync(label="shift1")
            ctx.put(a, b, to=lambda s_: (s_ + 2) % p_, dst_off=4, size=4)
            ctx.sync(label="shift2")
        return ctx.value(b)

    _, ledger = lpf.exec_(mesh, spmd, None, out_specs=P("x"),
                          return_ledger=True)
    slot_a, slot_b = _make_slot(0, 4), _make_slot(1, 8)
    for r, (shift, off) in zip(ledger.records, ((1, 0), (2, 4))):
        msgs = [Msg(s, (s + shift) % p, slot_a, 0, slot_b, off, 4,
                    origin="put") for s in range(p)]
        fresh = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
        assert dataclasses.replace(fresh.cost, label=r.label) == r, \
            (fresh.cost, r)
    return len(ledger.records)


def main(csv: bool = True):
    out = []
    b_rows = bench_bucketed()
    per_layer = next(r for r in b_rows if r[0] == "per-layer")
    bucketed = next(r for r in b_rows if r[0] == "bucketed")
    for name, ss, rounds, wire, ms in b_rows:
        out.append(("grad_sync", name, ss, rounds, wire, f"{ms:.3f}"))
    ratio = per_layer[1] / bucketed[1]
    assert ratio >= 4, f"superstep reduction {ratio}x < 4x"
    assert abs(bucketed[3] - per_layer[3]) <= 4 * LAYER_ELEMS * 4

    r_rows = bench_replay()
    for name, plans, ms in r_rows:
        out.append(("replay", name, plans, "", "", f"{ms:.1f}"))
    cold = next(r for r in r_rows if r[0] == "eager-cold")
    replay = next(r for r in r_rows if r[0] == "program-replay")
    assert replay[2] < cold[2], "replay must beat cold per-superstep planning"

    n = check_ledger_bit_for_bit()
    out.append(("ledger", "bit-for-bit", n, "", "", "ok"))

    if csv:
        print("bench,name,supersteps_or_plans,rounds,wire_bytes,ms")
        for row in out:
            print(",".join(str(x) for x in row))
        print(f"# per-layer -> bucketed superstep reduction: {ratio:.1f}x")
        print(f"# replay speedup vs eager-cold: "
              f"{cold[2] / replay[2]:.1f}x  (vs eager-warm: "
              f"{r_rows[1][2] / replay[2]:.1f}x)")
    return out


if __name__ == "__main__":
    main()
