"""Program replay + bucketed gradient sync + async overlap: the BSP case
for fewer, fatter — and overlapping — h-relations.

Three measurements, against the acceptance bars of the SuperstepProgram
and async-overlap PRs:

1. **Bucketed grad sync** — an 8-layer gradient pytree synced across a
   q=8 pod axis three ways at *equal gradient bytes*: per-layer (one
   rs+ag pair per layer — the naive schedule), bucketed (4 layers per
   bucket -> supersteps / 4), and fully flattened (1 pair).  The ledger
   superstep count must drop >= 4x per-layer -> bucketed, and the
   executed ledger must equal the plan-time prediction bit-for-bit.

2. **Recorded-program replay** — a recorded 8-superstep program
   replayed N times at trace time, against eager per-superstep sync
   with (a) cold planning each iteration and (b) a warm plan cache.
   Replay pays one program-signature per iteration instead of one plan
   (or plan-signature) per superstep, and skips the optimizer after the
   first pass — the re-planning overhead the plan/cache/execute split
   still paid per superstep.

3. **Async bucket overlap** — the 8-layer grad sync bucketed 2 layers
   per bucket, synchronous (BSP fence between buckets enforced) vs
   overlapped (bucket k+1's reduce-scatter issued before bucket k's
   all-gather, DDP style).  The overlapped schedule must win on
   wall-clock at p >= 4, and the recorded LPF bucket pipeline must
   ledger its overlapped supersteps exactly as planned
   (``overlap_cost`` of the member plans, bit for bit).
"""

from __future__ import annotations

import dataclasses
import os
import statistics
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.bsp.pod_sync import pod_allreduce
from repro.core import (CostLedger, LPF_SYNC_DEFAULT, Msg, PlanCache,
                        ProgramCache, ProgramStep, Slot, compat,
                        overlap_cost, plan_sync, program_signature)
from repro.core.machine import CPU_HOST, probe


# --------------------------------------------------------------------------
# 1. bucketed gradient sync: superstep count at equal bytes
# --------------------------------------------------------------------------

LAYERS = 8
LAYER_ELEMS = 1 << 14          # 64 KiB per layer (f32)


def bench_bucketed(q: int = 8):
    mesh = compat.make_mesh((q,), ("x",))
    grads = {f"layer{i}": jnp.arange(LAYER_ELEMS, dtype=jnp.float32) + i
             for i in range(LAYERS)}
    specs = jax.tree.map(lambda _: P(), grads)
    layer_bytes = LAYER_ELEMS * 4
    rows = []
    for name, bucket in (("per-layer", 1),
                         ("bucketed", 4 * layer_bytes),
                         ("flat", None)):
        ledger = CostLedger()
        method = "bucketed" if bucket is not None else "rs+ag"

        def body(g):
            return pod_allreduce(g, q, "x", mean=True, ledger=ledger,
                                 method=method, bucket_bytes=bucket)

        fn = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                      out_specs=specs, check_vma=False))
        jax.block_until_ready(fn(grads))
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn(grads)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 10
        rows.append((name, ledger.supersteps, ledger.rounds,
                     ledger.wire_bytes, dt * 1e3))
    return rows


# --------------------------------------------------------------------------
# 2. recorded-program replay vs eager per-superstep planning
# --------------------------------------------------------------------------

N_STEPS = 8
N_ITERS = 200


def _make_slot(sid, size):
    return Slot(sid=sid, name=f"s{sid}", size=size,
                dtype=np.dtype("float32"), kind="global",
                orig_shape=(size,))


def _fresh_trace(p: int, it: int):
    """The same 8-superstep shift program staged through fresh slots
    each iteration — what a collective called in a loop produces."""
    steps = []
    for k in range(N_STEPS):
        a = _make_slot(10_000 * it + 2 * k, 64)
        b = _make_slot(10_000 * it + 2 * k + 1, 64)
        msgs = tuple(Msg(s, (s + k + 1) % p, a, 0, b, 0, 64, origin="put")
                     for s in range(p))
        steps.append(ProgramStep(msgs, LPF_SYNC_DEFAULT, f"s{k}"))
    return steps


def bench_replay(p: int = 8):
    machine = probe({"x": p}, CPU_HOST)
    rows = []

    # (a) eager, cold planner: plan every superstep every iteration
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        for st in _fresh_trace(p, it):
            plan_sync(list(st.msgs), p, st.attrs)
    rows.append(("eager-cold", N_ITERS * N_STEPS,
                 (time.perf_counter() - t0) * 1e3))

    # (b) eager, warm plan cache: one signature per superstep per iter
    cache = PlanCache()
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        for st in _fresh_trace(p, it):
            cache.get_or_plan(list(st.msgs), p, st.attrs)
    rows.append(("eager-warm", cache.stats.misses,
                 (time.perf_counter() - t0) * 1e3))

    # (c) recorded replay: one canonical-order pass + one program
    # signature per iteration (the flush shares the order between the
    # cache lookup and materialize, as LPFContext does); steps the
    # optimizer left untouched reuse their staged messages verbatim
    from repro.core import canonical_order
    pcache = ProgramCache()
    t0 = time.perf_counter()
    for it in range(N_ITERS):
        steps = _fresh_trace(p, it)
        order = canonical_order(steps)
        prog = pcache.get_or_build(steps, p, machine, order=order)
        prog.materialize(steps, order=order)
    rows.append(("program-replay", pcache.stats.misses,
                 (time.perf_counter() - t0) * 1e3))
    return rows


def check_ledger_bit_for_bit(p: int = 8):
    """Executed ledger entries must equal the plans' predictions exactly
    (label aside) — run one recorded program on a real mesh and compare
    against from-scratch plans of its optimized tables.  The two
    independent shifts are batched or overlapped by the optimizer
    (their merged/overlapped record must still equal the fresh plans'
    combined prediction)."""
    mesh = compat.make_mesh((p,), ("x",))
    from repro import core as lpf

    def spmd(ctx, s, p_, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * p_)
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))
        with ctx.program():
            ctx.put(a, b, to=lambda s_: (s_ + 1) % p_, size=4)
            ctx.sync(label="shift1")
            ctx.put(a, b, to=lambda s_: (s_ + 2) % p_, dst_off=4, size=4)
            ctx.sync(label="shift2")
        return ctx.value(b)

    _, ledger = lpf.exec_(mesh, spmd, None, out_specs=P("x"),
                          return_ledger=True)
    slot_a, slot_b = _make_slot(0, 4), _make_slot(1, 8)
    plans = [plan_sync([Msg(s, (s + shift) % p, slot_a, 0, slot_b, off, 4,
                            origin="put") for s in range(p)],
                       p, LPF_SYNC_DEFAULT)
             for shift, off in ((1, 0), (2, 4))]
    if len(ledger.records) == 1:
        r = ledger.records[0]
        if r.method.startswith("overlap["):
            fresh = overlap_cost([pl.cost for pl in plans], label=r.label)
        else:       # the merge gate batched them into one superstep
            msgs = [Msg(s, (s + shift) % p, slot_a, 0, slot_b, off, 4,
                        origin="put")
                    for shift, off in ((1, 0), (2, 4)) for s in range(p)]
            fresh = dataclasses.replace(
                plan_sync(msgs, p, LPF_SYNC_DEFAULT).cost, label=r.label)
        assert fresh == r, (fresh, r)
    else:
        for r, pl in zip(ledger.records, plans):
            assert dataclasses.replace(pl.cost, label=r.label) == r, \
                (pl.cost, r)
    return len(ledger.records)


# --------------------------------------------------------------------------
# 3. async overlap: fenced synchronous buckets vs the DDP pipeline
# --------------------------------------------------------------------------

OVERLAP_REPS = 30
OVERLAP_P = 4        # mesh size of the overlap scenario (and its assert)


def bench_overlap(p: int = OVERLAP_P, layers: int = 8,
                  layer_elems: int = 1 << 16):
    """The overlapped bucketed 8-layer grad sync vs the synchronous
    (fenced) bucketed path at equal buckets/bytes.

    Two observables per method:

    * **wall-clock** — paired, order-alternating reps (adjacent-in-time
      measurements cancel host drift); the per-pair ratio's median is
      the schedule comparison.  NOTE: when the host has fewer cores
      than device threads (this repo's 2-core CI container time-slices
      8 XLA host devices), independent collectives cannot actually run
      concurrently and the ratio is a statistical tie by construction —
      the strict "overlap wins" assert applies only on hosts with at
      least one core per device thread.
    * **predicted seconds** — the DCN machine model's price of each
      *ledger*: the fenced path records 2B sequential supersteps, the
      overlapped path records its own schedule
      ([rs0][ag0||rs1]...[agB-1], overlap groups priced by
      ``overlap_cost``).  This is the auditable cost-model claim and
      must improve strictly.
    """
    mesh = compat.make_mesh((p,), ("x",))
    grads = {f"layer{i}": jnp.arange(layer_elems, dtype=jnp.float32) + i
             for i in range(layers)}
    specs = jax.tree.map(lambda _: P(), grads)
    bucket = 2 * layer_elems * 4            # 2 layers per bucket
    fns, ledgers = {}, {}
    for method in ("bucketed_fenced", "bucketed_overlap"):
        ledger = CostLedger()

        def body(g, method=method, ledger=ledger):
            return pod_allreduce(g, p, "x", mean=True, ledger=ledger,
                                 method=method, bucket_bytes=bucket)

        fns[method] = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False))
        jax.block_until_ready(fns[method](grads))   # compile + warm up
        ledgers[method] = ledger

    def timed(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(grads))
        return time.perf_counter() - t0

    times = {m: [] for m in fns}
    for rep in range(OVERLAP_REPS):
        order = tuple(fns) if rep % 2 == 0 else tuple(reversed(tuple(fns)))
        for m in order:
            times[m].append(timed(fns[m]))
    paired_ratio = statistics.median(
        s / o for s, o in zip(times["bucketed_fenced"],
                              times["bucketed_overlap"]))

    # the DCN machine model's verdict: both ledgers record their own
    # schedule (the overlapped one carries overlap_cost-priced groups),
    # so predicted time is just the ledger sum
    from repro.core.machine import TPU_V5E, probe as _probe
    dcn = _probe({"pod": p}, TPU_V5E)
    rows = []
    for m in fns:
        rows.append((m, ledgers[m].supersteps, ledgers[m].total_wire_bytes,
                     statistics.median(times[m]) * 1e3,
                     ledgers[m].predicted_seconds(dcn) * 1e6))
    return rows, paired_ratio


def check_overlap_ledger_bit_for_bit(p: int = 8):
    """The recorded LPF bucket pipeline — which the DAG schedule search
    now emits as [rs0||rs1][ag0||ag1] (the reduce-scatters are mutually
    ready and commute; each all-gather depends only on its own bucket)
    — must ledger every overlap group exactly as planned: rebuild the
    member plans from scratch and compare ``overlap_cost`` of them
    against the executed records."""
    mesh = compat.make_mesh((p,), ("x",))
    from repro import bsp
    from repro import core as lpf

    box = {}

    def wrapped(_):
        ctx = lpf.LPFContext(("x",))
        box["ledger"] = ctx.ledger
        x0 = (jnp.arange(float(p)) + ctx.pid).astype(jnp.float32)
        x1 = (jnp.arange(float(p)) * 2 - ctx.pid).astype(jnp.float32)
        with ctx.program("buckets"):
            h0 = bsp.allreduce_start(ctx, x0, label="b0")
            h1 = bsp.allreduce_start(ctx, x1, label="b1")
        return bsp.allreduce_done(ctx, h0) + bsp.allreduce_done(ctx, h1)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh, in_specs=(P(),),
                                  out_specs=P(), check_vma=False))
    jax.block_until_ready(fn(jnp.zeros(1)))
    records = box["ledger"].records
    assert [r.method for r in records] == \
        ["overlap[fused_rs+fused_rs]", "overlap[fused_ag+fused_ag]"], \
        records

    w = 1
    src, buf, out = (_make_slot(i, [p, 1, p][i]) for i in range(3))
    rs = [Msg(s, d, src, d * w, buf, 0, w) for s in range(p)
          for d in range(p)]
    ag = [Msg(s, d, buf, 0, out, s * w, w) for s in range(p)
          for d in range(p)]
    rs_plan = plan_sync(rs, p, LPF_SYNC_DEFAULT.replace(reduce_op="sum"))
    ag_plan = plan_sync(ag, p, LPF_SYNC_DEFAULT)
    fresh_rs = overlap_cost([rs_plan.cost, rs_plan.cost],
                            label=records[0].label)
    assert fresh_rs == records[0], (fresh_rs, records[0])
    fresh_ag = overlap_cost([ag_plan.cost, ag_plan.cost],
                            label=records[1].label)
    assert fresh_ag == records[1], (fresh_ag, records[1])
    return len(records)


# --------------------------------------------------------------------------
# 4. compiled replay: fused whole-program XLA vs per-call dispatch
# --------------------------------------------------------------------------

COMPILED_ITERS = 64
COMPILED_ELEMS = 256             # small h: dispatch overhead dominates
COMPILED_BUCKET = 128            # -> 2 buckets (4 collectives) per iter
COMPILED_REPS = 5


def bench_compiled_replay(p: int = 8):
    """Per-iteration cost of a small-h bucketed-sync program, two ways:

    * **dispatched** — one jitted call per iteration with whole-program
      compilation off: every iteration pays a host-side jax dispatch
      plus the Python per-superstep execute path inside the trace-free
      replay (the pre-tentpole steady state);
    * **fused** — all ``COMPILED_ITERS`` iterations rolled into ONE
      jitted call via ``ctx.compile_loop`` (one ``lax.scan`` whose body
      is the compiled program): one dispatch, zero per-iteration Python.

    At 4 KiB payloads the work per iteration is trivial, so the ratio
    isolates exactly the dispatch overhead the tentpole removes.
    Returns ([(name, per_iter_us)], ratio, max_abs_err)."""
    mesh = compat.make_mesh((p,), ("x",))
    from repro import core as lpf
    from repro.bsp.pod_sync import lpf_bucketed_allreduce

    def one_iter(ctx, x):
        return lpf_bucketed_allreduce(ctx, x, COMPILED_BUCKET, mean=True)

    def dispatched(x):
        ctx = lpf.LPFContext(("x",))
        ctx.compile_programs = False
        return one_iter(ctx, x.reshape(-1))

    def fused(x):
        ctx = lpf.LPFContext(("x",))
        return ctx.compile_loop(one_iter, x.reshape(-1),
                                n_iters=COMPILED_ITERS, label="ddp")

    sm = lambda f: jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
        check_vma=False))
    disp_fn, fused_fn = sm(dispatched), sm(fused)

    x = (jnp.arange(p * COMPILED_ELEMS, dtype=jnp.float32)
         % 97.0) * 0.25 + 1.0
    jax.block_until_ready(disp_fn(x))           # compile + warm
    jax.block_until_ready(fused_fn(x))

    def run_disp():
        # block every iteration: (a) the faithful dispatched baseline —
        # each Python-issued step completes before the next is issued —
        # and (b) required on oversubscribed hosts, where letting tens
        # of async 8-way host collectives queue up can deadlock XLA's
        # cross-module rendezvous (device threads >> cores)
        y = x
        for _ in range(COMPILED_ITERS):
            y = jax.block_until_ready(disp_fn(y))
        return y

    t_disp, t_fused = [], []
    for _ in range(COMPILED_REPS):
        t0 = time.perf_counter()
        y_disp = run_disp()
        t_disp.append((time.perf_counter() - t0) / COMPILED_ITERS)
        t0 = time.perf_counter()
        y_fused = jax.block_until_ready(fused_fn(x))
        t_fused.append((time.perf_counter() - t0) / COMPILED_ITERS)

    # numerics: repeated mean-allreduce is idempotent after the first
    # iteration, so both paths must land on the cross-pid mean
    ref = np.tile(np.asarray(x).reshape(p, COMPILED_ELEMS).mean(axis=0),
                  p)
    err = max(np.abs(np.asarray(y_disp) - ref).max(),
              np.abs(np.asarray(y_fused) - ref).max())
    d_us = statistics.median(t_disp) * 1e6
    f_us = statistics.median(t_fused) * 1e6
    return [("dispatched", d_us), ("fused", f_us)], d_us / f_us, float(err)


def compiled_replay_main(csv: bool = True):
    rows, ratio, err = bench_compiled_replay()
    assert err < 1e-4, f"fused/dispatched numerics diverged: {err}"
    assert ratio >= 2.0, \
        (f"fused replay must cut per-iteration dispatch overhead >= 2x "
         f"(got {ratio:.2f}x)")
    out = [("compiled_replay", name, COMPILED_ITERS, "", "",
            f"{us:.1f}us/iter") for name, us in rows]
    if csv:
        print("bench,name,iters,_,_,per_iter")
        for row in out:
            print(",".join(str(x) for x in row))
        print(f"# fused vs dispatched per-iteration speedup: {ratio:.1f}x "
              f"(max abs err {err:.2e})")
    return out


def main(csv: bool = True, compiled: bool = True):
    out = []
    b_rows = bench_bucketed()
    per_layer = next(r for r in b_rows if r[0] == "per-layer")
    bucketed = next(r for r in b_rows if r[0] == "bucketed")
    for name, ss, rounds, wire, ms in b_rows:
        out.append(("grad_sync", name, ss, rounds, wire, f"{ms:.3f}"))
    ratio = per_layer[1] / bucketed[1]
    assert ratio >= 4, f"superstep reduction {ratio}x < 4x"
    assert abs(bucketed[3] - per_layer[3]) <= 4 * LAYER_ELEMS * 4

    r_rows = bench_replay()
    for name, plans, ms in r_rows:
        out.append(("replay", name, plans, "", "", f"{ms:.1f}"))
    cold = next(r for r in r_rows if r[0] == "eager-cold")
    replay = next(r for r in r_rows if r[0] == "program-replay")
    assert replay[2] < cold[2], "replay must beat cold per-superstep planning"

    n = check_ledger_bit_for_bit()
    out.append(("ledger", "bit-for-bit", n, "", "", "ok"))

    o_rows, paired = bench_overlap()
    for name, ss, wire, ms, pred_us in o_rows:
        out.append(("overlap", name, ss, f"{pred_us:.1f}us_pred", wire,
                    f"{ms:.3f}"))
    o_sync = next(r for r in o_rows if r[0] == "bucketed_fenced")
    o_ovl = next(r for r in o_rows if r[0] == "bucketed_overlap")
    # overlap hides time, not traffic: flat totals must match
    assert o_sync[2] == o_ovl[2], \
        "overlap is a scheduling change: total wire must match"
    # the cost-model claim: the overlapped schedule is strictly cheaper
    # on the DCN machine (wire hidden + fences dropped)
    assert o_ovl[4] < o_sync[4], (o_ovl[4], o_sync[4])
    # the wall-clock claim: strict win where the host can actually run
    # the scenario's p device threads concurrently.  os.cpu_count()
    # reports hyperthreaded vCPUs (a 4-vCPU CI runner has 2 physical
    # cores), so require 2*p vCPUs.  Below that, independent
    # collectives execute time-sliced whatever the schedule says,
    # lockstep fencing even *reduces* rendezvous skew, and the
    # comparison measures only the host scheduler — so there the ratio
    # is reported, not enforced.
    concurrent_host = (os.cpu_count() or 1) >= 2 * OVERLAP_P
    if concurrent_host:
        assert paired > 1.0, \
            (f"overlapped bucketed sync must beat the fenced path "
             f"(paired ratio {paired:.3f})")
    else:
        print(f"# [report-only] paired wall-clock ratio {paired:.3f} on "
              f"a {os.cpu_count()}-vCPU host time-slicing p={OVERLAP_P} "
              f"device threads — schedule comparison not meaningful here")

    n_ovl = check_overlap_ledger_bit_for_bit()
    out.append(("overlap_ledger", "bit-for-bit", n_ovl, "", "", "ok"))

    if compiled:
        out += compiled_replay_main(csv=False)

    if csv:
        print("bench,name,supersteps_or_plans,rounds,wire_bytes,ms")
        for row in out:
            print(",".join(str(x) for x in row))
        print(f"# per-layer -> bucketed superstep reduction: {ratio:.1f}x")
        print(f"# replay speedup vs eager-cold: "
              f"{cold[2] / replay[2]:.1f}x  (vs eager-warm: "
              f"{r_rows[1][2] / replay[2]:.1f}x)")
        print(f"# bucketed overlap vs fenced sync: paired wall-clock "
              f"ratio {paired:.2f}x; predicted (DCN model) "
              f"{o_sync[4] / o_ovl[4]:.2f}x")
    return out


if __name__ == "__main__":
    main()
