"""DAG schedule search vs the adjacent-only peephole — canned traces.

The program optimizer is a cost-model-driven schedule search over the
trace's dependency DAG (``optimize_program(search=True)``, the default
and the cached/executed path).  This benchmark prices the searched
schedule against the PR-4-era adjacent-pairs peephole
(``search=False``) on the DCN machine model for canned traces the
peephole provably cannot schedule well:

1. **FFT redistribute** — two interleaved FFT instances (a batched
   spectral pipeline): ``[A.redist, A.reorder, B.redist, B.reorder]``.
   Each reorder depends on its own redistribute, the instances are
   independent.  The peephole can only overlap the *adjacent*
   independent pair (``A.reorder || B.redist``); the search hoists
   ``B.redist`` over two steps next to ``A.redist`` and emits
   ``[A.redist || B.redist][A.reorder || B.reorder]`` — one fewer
   barrier and one less time-equivalent exchange on the wire.

2. **8-layer bucketed gradient sync** — the DDP shape
   ``[rs0, ag0, ..., rs3, ag3]``.  The peephole's best is the pipeline
   ``[rs0][ag_k || rs_k+1]...[ag_3]`` (B+1 barriers, B+1 exchanges of
   time-equivalent wire); the search hoists all mutually ready
   reduce-scatters together: ``[rs0..rs3][ag0..ag3]`` — 2 barriers, 2
   time-equivalent exchanges.

3. **Fragmented fat relation** — two supersteps spreading messages over
   many slot pairs, each paying one coloured round per pair, WAR-coupled
   so overlap is inadmissible.  The search applies the *Valiant-aware
   attr rewrite* to each fat superstep (the merged table would double
   via-collisions, so the model keeps them separate): two-phase routing
   through the scratch slot beats the round-heavy direct schedules when
   the model's ``l`` dominates.  (The merge+rewrite combination is
   exercised by ``tests/test_schedule_search.py::
   test_merged_valiant_rewrite``, whose steps share one slot-pair
   space.)

Every searched schedule is validated against the numpy reference
interpreter bit-for-bit, the bucketed trace is additionally executed on
a real 8-device mesh where each ledger entry must equal its planned
cost exactly, and ``GUARD_BOUNDS_US`` records the expected DCN-model
times — the fast-tier guard (``tests/test_schedule_search.py``) fails
if any canned trace's optimized predicted cost regresses past them.
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import optimize_program, simulate_program
from repro.core.machine import TPU_V5E, probe

#: the DCN machine every canned trace is priced on
DCN = probe({"pod": 8}, TPU_V5E)

#: regression guard: optimized (searched) predicted DCN time per canned
#: trace, microseconds, with ~5% headroom.  The fast-tier guard test
#: fails when a canned trace's searched schedule prices above its bound
#: (a cost regression in the scheduler), or stops beating the peephole.
GUARD_BOUNDS_US = {
    # measured 375.25us searched (487.88 peephole, 600.50 in-order)
    "fft_redistribute": 395.0,
    # measured 525.25us searched (863.13 peephole, 1201.00 in-order)
    "bucketed_sync8": 552.0,
    # measured 3900.01us searched (4800.00 peephole == in-order)
    "fragmented_valiant": 4095.0,
}


# the canned trace builders live in repro.analysis.traces so the
# static analyzer CLI (``python -m repro.analysis``) lints and
# verifies exactly the shapes priced here
from repro.analysis.traces import (canned_bucketed_trace,
                                   canned_fft_trace,
                                   canned_fragmented_trace)


CANNED = {
    "fft_redistribute": canned_fft_trace,
    "bucketed_sync8": canned_bucketed_trace,
    "fragmented_valiant": canned_fragmented_trace,
}


def _differential_check(prog, steps, slots, p, seed=0):
    """Searched schedule == eager recorded trace, bit for bit, on the
    numpy reference interpreter."""
    rng = np.random.default_rng(seed)
    values = {s.sid: rng.integers(-10_000, 10_000,
                                  size=(p, s.size)).astype(np.int32)
              for s in slots}
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    tables = [(msgs, attrs) for msgs, attrs, _, _
              in prog.materialize(prog.slot_map(steps))]
    opt = simulate_program(tables, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), f"slot {sid} diverged"


def run_canned(name: str):
    """(searched, peephole, in-order) predicted DCN seconds + programs."""
    p, slots, steps, scratch = CANNED[name]()
    searched = optimize_program(steps, p, DCN, scratch=scratch)
    peephole = optimize_program(steps, p, DCN, scratch=scratch,
                                search=False)
    _differential_check(searched, steps, slots, p)
    return searched, peephole, p, steps


def check_executed_ledger_bit_for_bit(p: int = 8):
    """Execute the bucketed-sync shape through the real ``ctx.program``
    path on an 8-device mesh: every ledger entry must equal the planned
    cost of its schedule group bit-for-bit (singletons the member
    plan's cost, overlap groups ``overlap_cost`` of the member
    plans)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import bsp
    from repro import core as lpf
    from repro.core import compat, overlap_cost

    mesh = compat.make_mesh((p,), ("x",))
    box = {}

    def wrapped(_):
        ctx = lpf.LPFContext(("x",))
        box["ctx"] = ctx
        xs = [(jnp.arange(float(p)) * (i + 1) + ctx.pid).astype(
            jnp.float32) for i in range(3)]
        with ctx.program("buckets"):
            handles = [bsp.allreduce_start(ctx, x, label=f"b{i}")
                       for i, x in enumerate(xs)]
        outs = [bsp.allreduce_done(ctx, h) for h in handles]
        return sum(outs)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh, in_specs=(P(),),
                                  out_specs=P(), check_vma=False))
    jax.block_until_ready(fn(jnp.zeros(1)))
    ctx = box["ctx"]
    prog = ctx.last_program
    records = ctx.ledger.records
    assert len(records) == len(prog.groups())
    for rec, grp in zip(records, prog.groups()):
        costs = [prog.steps[i].plan.cost for i in grp]
        if len(costs) == 1:
            import dataclasses
            fresh = dataclasses.replace(costs[0], label=rec.label)
        else:
            fresh = overlap_cost(costs, label=rec.label)
        assert fresh == rec, (fresh, rec)
    return len(records)


def main(csv: bool = True):
    rows = []
    programs = {}
    for name in CANNED:
        searched, peephole, p, steps = run_canned(name)
        programs[name] = searched
        s_us = searched.predicted_seconds(DCN) * 1e6
        p_us = peephole.predicted_seconds(DCN) * 1e6
        o_us = searched.in_order_seconds(DCN) * 1e6
        # the acceptance bar: at least one merge/overlap the adjacent
        # pass missed, and a strict DCN-model improvement over it
        assert searched.n_hoisted + searched.n_rewritten >= 1, name
        assert s_us < p_us, (name, s_us, p_us)
        assert s_us <= GUARD_BOUNDS_US[name], \
            f"{name}: searched schedule {s_us:.1f}us regressed past " \
            f"guard {GUARD_BOUNDS_US[name]}us"
        rows.append((name, len(steps), len(searched.groups()),
                     len(peephole.groups()), searched.n_hoisted,
                     searched.n_rewritten, f"{o_us:.1f}", f"{p_us:.1f}",
                     f"{s_us:.1f}", f"{p_us / s_us:.2f}"))
    n_records = check_executed_ledger_bit_for_bit()
    rows.append(("executed_ledger", "", "", "", "", "", "", "",
                 f"{n_records}_records", "bit-for-bit"))
    if csv:
        print("trace,steps,groups_searched,groups_peephole,hoists,"
              "rewrites,in_order_us,peephole_us,searched_us,speedup")
        for row in rows:
            print(",".join(str(x) for x in row))
        for name, searched in programs.items():
            print(f"\n# --- {name} ---")
            print(searched.explain(DCN))
    return rows


if __name__ == "__main__":
    main()
