"""Paper Table 4: LPF PageRank vs the 'pure dataflow' baseline.

Synthetic R-MAT webgraphs stand in for cage15/uk-2002 (offline container).
As in the paper: the LPF version handles dangling mass and checks an
eps=1e-7 tolerance; the baseline (SparkPageRank semantics) does neither —
the asymmetry can only favour the baseline.  Reported per graph: n=1,
n=10 end-to-end, n=n_eps, and seconds/iteration.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import (dataflow_pagerank, lpf_pagerank,
                              partition_graph, rmat_graph)
from repro.core import compat


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps


def main(csv=True, sizes=((1 << 12, 6), (1 << 14, 6))):
    mesh = compat.make_mesh((8,), ("x",))
    rows = []
    for n, avg_deg in sizes:
        edges = rmat_graph(n, n * avg_deg, seed=1)
        g = partition_graph(edges, n, 8)

        def lpf_run(iters):
            return lpf_pagerank(mesh, g, tol=0.0 if iters else 1e-7,
                                max_iter=iters or 200)

        # n_eps: run to tolerance
        t0 = time.perf_counter()
        _, n_eps, _ = lpf_pagerank(mesh, g, tol=1e-7, max_iter=200)
        t_eps = time.perf_counter() - t0
        t1 = _time(lambda: lpf_pagerank(mesh, g, tol=0.0, max_iter=1)[0])
        t10 = _time(lambda: lpf_pagerank(mesh, g, tol=0.0, max_iter=10)[0],
                    reps=1)
        s_it_lpf = max(t10 - t1, 1e-9) / 9

        tb1 = _time(lambda: dataflow_pagerank(edges, n, 1))
        tb10 = _time(lambda: dataflow_pagerank(edges, n, 10), reps=1)
        s_it_df = max(tb10 - tb1, 1e-9) / 9

        rows.append((f"pagerank_rmat{n}", n, edges.shape[0], int(n_eps),
                     tb1, tb10, s_it_df, t1, t10, t_eps, s_it_lpf,
                     g.h_bytes()))
    if csv:
        print("name,n,edges,n_eps,df_n1_s,df_n10_s,df_s_per_it,"
              "lpf_n1_s,lpf_n10_s,lpf_neps_s,lpf_s_per_it,halo_h_bytes")
        for r in rows:
            print(",".join(f"{x:.5g}" if isinstance(x, float) else str(x)
                           for x in r))
    return rows


if __name__ == "__main__":
    main()
