"""§Roofline: derive the three terms per (arch x shape x mesh) from the
dry-run artifacts (experiments/dryrun/*.json).

  compute    = HLO_FLOPs(device) / peak_FLOPs
  memory     = HLO_traffic(device) / HBM_bw   (loop-aware unfused-bytes
               census of the compiled HLO: an upper bound that XLA
               fusion tightens on the real target)
  collective = collective_bytes(device) / link_bw     (ICI; the pod axis
               contribution is reported separately from the multi mesh)

HLO_FLOPs / bytes / collective_bytes are the scan-calibrated values (see
launch/dryrun for the extrapolation); per-device where cost_analysis is
per-partition (verified against analytic model flops).
"""

from __future__ import annotations

import glob
import json
import os

from repro.core import TPU_V5E
from repro.core.hlo_analysis import RooflineTerms


def load_terms(art: dict) -> RooflineTerms:
    hw = TPU_V5E
    link = hw.link("dcn").bw if art["mesh"] == "multi" else hw.link("ici").bw
    return RooflineTerms(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        chips=art["chips"],
        hlo_flops=art["hlo_flops"],
        hlo_bytes=art["hlo_bytes"],
        collective_bytes=art["collective_bytes"],
        model_flops=art["model_flops"],
        peak_flops=hw.peak_flops_bf16,
        hbm_bw=hw.hbm_bw,
        link_bw=hw.link("ici").bw,
        memory_per_device=art["memory"]["per_device_bytes"])


def main(csv=True, art_dir="experiments/dryrun"):
    rows = []
    print(RooflineTerms.header())
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if art.get("status") != "ok":
            arch, shape, mesh = art["arch"], art["shape"], art["mesh"]
            print(f"{arch:<24}{shape:<13}{mesh:<10}{'-- skipped: ' + art.get('reason', '')}")
            continue
        t = load_terms(art)
        print(t.row())
        rows.append(t)
    if rows:
        worst = min(rows, key=lambda t: t.roofline_fraction)
        collb = max(rows, key=lambda t: t.t_collective
                    / max(t.t_bound, 1e-12))
        print(f"\nworst roofline fraction: {worst.arch}/{worst.shape}"
              f"/{worst.mesh} at {worst.roofline_fraction:.2%}")
        print(f"most collective-bound:  {collb.arch}/{collb.shape}"
              f"/{collb.mesh}")
    return rows


if __name__ == "__main__":
    main()
