"""Allreduce sweep: fused reduction supersteps vs coloured rounds.

Times ``bsp.allreduce`` two ways over p in {4, 8} and n up to 2**22:

* ``fused``  — the default path: the reduce-scatter relation lowers to
  one ``lax.psum_scatter`` and the allgather to one ``lax.all_gather``
  (2 rounds total; ledger wire = 2(n/p)(p-1) * 4 bytes per process).
* ``direct`` — ``SyncAttributes(method="direct")`` forces the generic
  edge-coloured schedule the collectives paid before reduction
  supersteps existed: 2(p-1) ``ppermute`` rounds for the same wire.

The fused path must win for n >= 2**20 (the acceptance bar); the gap is
the l-term the BSP ledger predicts, l * (2p - 4).
"""

from __future__ import annotations

import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import bsp, core as lpf
from repro.core import SyncAttributes, compat


def _time(fn, x, reps=5):
    jax.block_until_ready(fn(x))           # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _allreduce_fn(mesh, attrs):
    def spmd(ctx, s, p, x):
        return bsp.allreduce(ctx, x, attrs=attrs)

    def run(x):
        return lpf.exec_(mesh, spmd, x, in_specs=P(), out_specs=P("x"))

    return jax.jit(run)


def sweep(ps=(4, 8), log_ns=(18, 20, 22), reps=5):
    rows = []
    for p in ps:
        mesh = compat.make_mesh((p,), ("x",))
        fused = _allreduce_fn(mesh, SyncAttributes())
        direct = _allreduce_fn(mesh, SyncAttributes(method="direct"))
        for log_n in log_ns:
            n = 1 << log_n
            x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                            jnp.float32)
            t_fused = _time(fused, x, reps)
            t_direct = _time(direct, x, reps)
            rows.append((p, n, t_fused, t_direct, t_direct / t_fused))
    return rows


def main(csv=True, log_ns=(18, 20, 22)):
    rows = sweep(log_ns=log_ns)
    if csv:
        print("p,n,t_fused_s,t_direct_s,speedup")
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.6f},{r[3]:.6f},{r[4]:.2f}")
    return rows


if __name__ == "__main__":
    main()
