"""Paper Fig. 3: the immortal BSP FFT vs the vendor library.

``jnp.fft.fft`` on the same backend plays the MKL/FFTW role (a tuned
native FFT); the LPF FFT runs on p = 8 emulated processes with real
collectives in between, i.e. with all of the model-compliance machinery
the paper claims costs nothing.  Reported: time per transform and the
ratio, plus the predicted BSP comm cost from the ledger.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import bsp_fft, fft_flops, fft_h_bytes
from repro.core import probe, CPU_HOST
from repro.core import compat


def _time(fn, x, reps=5):
    jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main(csv=True, max_log2=18):
    mesh = compat.make_mesh((8,), ("x",))
    rows = []
    rng = np.random.default_rng(0)
    for k in range(10, max_log2 + 1, 2):
        n = 1 << k
        x = jnp.asarray(rng.standard_normal(n)
                        + 1j * rng.standard_normal(n), jnp.complex64)
        t_ref = _time(jax.jit(jnp.fft.fft), x)
        t_lpf = _time(jax.jit(lambda v: bsp_fft(mesh, v)), x)
        # correctness alongside the timing
        err = float(jnp.abs(bsp_fft(mesh, x) - jnp.fft.fft(x)).max())
        machine = probe({"x": 8}, CPU_HOST)
        t_comm_pred = machine.t_comm(fft_h_bytes(n, 8), supersteps=2)
        rows.append(("fft", n, t_ref * 1e6, t_lpf * 1e6,
                     t_lpf / t_ref, t_comm_pred * 1e6, err))
    if csv:
        print("name,n,vendor_us,lpf_us,ratio,pred_comm_us,max_err")
        for r in rows:
            print(",".join(f"{x:.6g}" if isinstance(x, float) else str(x)
                           for x in r))
    return rows


if __name__ == "__main__":
    main()
