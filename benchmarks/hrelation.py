"""Paper Table 3: measure the BSP machine constants (g, l) by timing
total exchanges — ``lpf_probe``'s online-benchmark mode.

For word sizes w we run total exchanges of h bytes per process, fit
T(h) = g*h + l per the paper's estimators
    g ~ (T(n_max) - T(2p)) / (n_max - 2p)
    l ~ max(T(0), 2 T(p) - T(2p))
and report (g, l) normalised by the memcpy rate r, as Table 3 does.
The CPU backend numbers calibrate the *methodology*; the v5e column is
the hardware-model table the dry-run probe uses.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import bsp, core as lpf
from repro.core import compat


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _exchange_fn(mesh, n_elems):
    """Total exchange of n_elems f32 per process (h = 4*n_elems bytes)."""
    def spmd(ctx, s, p, x):
        return bsp.alltoall(ctx, x)

    def run(x):
        return lpf.exec_(mesh, spmd, x, in_specs=P(), out_specs=P("x"))

    p = int(np.prod(list(mesh.shape.values())))
    pad = max(p, n_elems - n_elems % p) if n_elems % p else n_elems
    x = jnp.arange(max(pad, p), dtype=jnp.float32)
    return jax.jit(run), x


def measure_constants(mesh, n_max_bytes=1 << 22):
    p = int(np.prod(list(mesh.shape.values())))
    # memcpy rate r
    big = jnp.arange(1 << 22, dtype=jnp.float32)
    t_cp = _time(jax.jit(lambda a: a + 1.0), big)
    r = t_cp / big.nbytes                      # s/byte

    def T(h_bytes):
        n = max(p, h_bytes // 4)
        fn, x = _exchange_fn(mesh, n)
        return _time(fn, x)

    t0 = T(0)
    tp = T(4 * p)
    t2p = T(8 * p)
    tmax = T(n_max_bytes)
    g = (tmax - t2p) / (n_max_bytes - 8 * p)
    l = max(t0, 2 * tp - t2p)
    return {"p": p, "r_s_per_byte": r, "g_s_per_byte": g, "l_s": l,
            "g_norm": g / r, "l_words": l / max(g * 8, 1e-30)}


def main(csv=True):
    rows = []
    for p in (4, 8):
        mesh = compat.make_mesh((p,), ("x",))
        m = measure_constants(mesh)
        rows.append(("hrelation_cpu", p, m["g_s_per_byte"], m["l_s"],
                     m["g_norm"], m["l_words"]))
    # the v5e model column (what lpf_probe serves on the target)
    for axes in ({"data": 16, "model": 16},
                 {"pod": 2, "data": 16, "model": 16}):
        mm = lpf.probe(axes, lpf.TPU_V5E)
        rows.append((f"probe_v5e_p{mm.p}", mm.p, mm.g, mm.l,
                     mm.g / mm.r, mm.l / max(mm.g * 8, 1e-30)))
    if csv:
        print("name,p,g_s_per_byte,l_s,g_norm,l_words")
        for r_ in rows:
            print(",".join(str(x) for x in r_))
    return rows


if __name__ == "__main__":
    main()
