"""Serve-loop latency under the model-priced admission controller.

A burst-arrival synthetic workload runs through
:class:`repro.runtime.server.LPFServer` over the pure-LPF
:class:`~repro.runtime.server.ProgramDecodeEngine`; per completed
request we record wall latency (submit -> terminal) and model-clock
latency (admission vclock -> completion vclock), aggregated per decode
bucket into p50/p99, next to the SLO accounting the admission
controller promises: zero deadline misses for admitted requests and a
classified reason for every refusal.

``python -m benchmarks.serve_latency`` prints the CSV;
``benchmarks.run_all`` captures it as ``BENCH_serve.json`` so the
nightly workflow tracks serve latency and admission mix across PRs.
"""

from __future__ import annotations

import os
import statistics
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def _pctl(xs, q):
    xs = sorted(xs)
    if not xs:
        return float("nan")
    i = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
    return xs[i]


def main(n_requests: int = 120, burst: int = 6, seed: int = 0):
    from repro.runtime.server import (LPFServer, ProgramDecodeEngine,
                                      synthetic_requests)

    eng = ProgramDecodeEngine(buckets=((2, 16), (4, 16), (4, 32)))
    srv = LPFServer(eng, max_queue=16)
    reqs = synthetic_requests(
        n_requests, seed, eng.buckets(),
        token_cost_s=max(eng.token_seconds(b) for b in eng.buckets()),
        deadline_scale=80.0)

    t0 = time.perf_counter()
    for i in range(0, len(reqs), burst):
        for r in reqs[i:i + burst]:
            srv.submit(r)
        srv.step()
    srv.run_until_idle()
    health = srv.drain()
    wall = time.perf_counter() - t0

    outs = srv.take_outcomes()
    per_bucket: dict = {}
    for out in outs.values():
        if out.status == "completed":
            per_bucket.setdefault(out.bucket, []).append(out)

    rows = []
    print("bucket,completed,wall_p50_ms,wall_p99_ms,"
          "model_p50_ms,model_p99_ms,tokens")
    for bucket in sorted(per_bucket):
        done = per_bucket[bucket]
        walls = [o.wall_s * 1e3 for o in done]
        models = [(o.completion_v - o.admit_v) * 1e3 for o in done]
        row = {
            "bucket": f"{bucket[0]}x{bucket[1]}",
            "completed": len(done),
            "wall_p50_ms": round(_pctl(walls, 0.50), 3),
            "wall_p99_ms": round(_pctl(walls, 0.99), 3),
            "model_p50_ms": round(_pctl(models, 0.50), 6),
            "model_p99_ms": round(_pctl(models, 0.99), 6),
            "tokens": sum(len(o.tokens) for o in done),
        }
        rows.append(row)
        print(",".join(str(row[k]) for k in (
            "bucket", "completed", "wall_p50_ms", "wall_p99_ms",
            "model_p50_ms", "model_p99_ms", "tokens")))

    slo = {
        "bucket": "TOTAL",
        "submitted": health["submitted"],
        "admitted": health["admitted"],
        "completed": health["completed"],
        "shed": health["shed"],
        "rejected": health["rejected_total"],
        "deadline_misses": health["deadline_misses"],
        "decode_fallbacks": health["decode_fallbacks"],
        "queue_peak": health["queue_peak"],
        "level_peak": health["level_peak"],
        "wall_s": round(wall, 3),
        "tok_per_s": round(health["tokens_decoded"] / wall, 1),
    }
    rows.append(slo)
    print(f"\nadmission: {slo['admitted']}/{slo['submitted']} admitted, "
          f"{slo['rejected']} rejected, {slo['shed']} shed, "
          f"{slo['deadline_misses']} deadline misses")
    print(f"throughput: {health['tokens_decoded']} tokens in "
          f"{wall:.3f}s ({slo['tok_per_s']} tok/s), "
          f"queue peak {slo['queue_peak']}, "
          f"ladder peak level {slo['level_peak']}")
    if slo["deadline_misses"]:
        raise SystemExit("SLO violation: admitted request(s) missed "
                         "their model-clock deadline")
    mean_wall = statistics.fmean(
        o.wall_s for o in outs.values()
        if o.status == "completed") if per_bucket else float("nan")
    print(f"mean completed wall latency: {mean_wall * 1e3:.2f} ms")
    return rows


if __name__ == "__main__":
    main()
