"""Paper Fig. 2: n small messages round-robin — model compliance of the
back-end, and the direct-vs-Bruck trade-off.

The paper shows MPI back-ends going super-linear in message count while
ibverbs stays affine.  Our XLA analogue: wall time and *collective
launches* as a function of message count for the three methods.  Direct
pays one ppermute round per relation degree; Bruck caps rounds at
ceil(log2 p) for O(log p)x payload; the fused path detects the canonical
exchange.  Compliance = affine scaling of time in total bytes, with the
round count matching the ledger's promise.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import core as lpf
from repro.core import SyncAttributes
from repro.core import compat


def _roundrobin(mesh, n_msgs, w, method):
    """Each pid sends n_msgs messages of w f32 to successive neighbours."""
    p = int(np.prod(list(mesh.shape.values())))

    def spmd(ctx, s, p_, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p_ * n_msgs)
        src = ctx.register_global("src",
                                  jnp.arange(n_msgs * w, dtype=jnp.float32))
        dst = ctx.register_global("dst", jnp.zeros(n_msgs * w))
        msgs = []
        for s_ in range(p_):
            for i in range(n_msgs):
                d = (s_ + 1 + i) % p_
                msgs.append((s_, d, src, i * w, dst, i * w, w))
        ctx.put_msgs(msgs)
        ctx.sync(SyncAttributes(method=method))
        return ctx.tensor(dst)

    def run(_):
        return lpf.exec_(mesh, spmd, out_specs=P("x"))

    _, ledger = lpf.exec_(mesh, spmd, out_specs=P("x"), return_ledger=True)
    fn = jax.jit(lambda _: run(_))
    fn(0)
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = fn(0)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / reps
    rec = ledger.records[0]
    return dt, rec.rounds, rec.wire_bytes


def main(csv=True):
    mesh = compat.make_mesh((8,), ("x",))
    rows = []
    for method in ("direct", "bruck"):
        for n_msgs in (1, 2, 4, 7):
            if method == "bruck" and n_msgs > 1:
                continue   # bruck handles unique (src,dst) pairs
            dt, rounds, wire = _roundrobin(mesh, n_msgs, 64, method)
            rows.append((f"messages_{method}", n_msgs, rounds, wire,
                         dt * 1e6))
    if csv:
        print("name,n_msgs,rounds,wire_bytes,us_per_sync")
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    main()
