"""Benchmark orchestrator — one entry per paper table/figure.

``python -m benchmarks.run [--fast]`` prints each benchmark's CSV block:
  hrelation  -> paper Table 3 (g, l constants; probe's v5e model column)
  messages   -> paper Fig. 2 (n-message compliance, direct vs Bruck)
  fft        -> paper Fig. 3 (immortal FFT vs vendor FFT)
  pagerank   -> paper Table 4 (LPF vs pure-dataflow PageRank)
  roofline   -> §Roofline terms from the dry-run artifacts (if present)
"""

import argparse
import os
import sys
import traceback

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import allreduce, fft, hrelation, messages, pagerank, roofline

    jobs = {
        "hrelation": lambda: hrelation.main(),
        "messages": lambda: messages.main(),
        "allreduce": lambda: allreduce.main(
            log_ns=(16, 18) if args.fast else (18, 20, 22)),
        "fft": lambda: fft.main(max_log2=14 if args.fast else 18),
        "pagerank": lambda: pagerank.main(
            sizes=((1 << 10, 6),) if args.fast
            else ((1 << 12, 6), (1 << 14, 6))),
        "roofline": lambda: roofline.main(),
    }
    failed = []
    for name, job in jobs.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====")
        try:
            job()
        except Exception:                      # report, keep going
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
