"""Machine-readable benchmark runner — the perf trajectory across PRs.

``python -m benchmarks.run_all --json [DIR]`` runs every benchmark and
writes one ``BENCH_<name>.json`` per benchmark plus a
``BENCH_summary.json`` roll-up into DIR (default ``bench-results/``).
Each file carries the benchmark's structured rows (when its ``main``
returns them), its captured CSV stdout, wall-clock, and enough platform
metadata (jax version, device/core counts) to compare runs across
machines.  The nightly workflow uploads DIR as an artifact, so every
PR's perf numbers are recorded instead of scrolling away in logs.

``--fast`` mirrors ``benchmarks.run --fast`` (CI-friendly sizes);
``--only NAME`` runs a single benchmark.
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import platform
import sys
import time
import traceback

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def _jobs(fast: bool):
    from . import (allreduce, fft, hrelation, messages, pagerank,
                   program_replay, roofline, schedule_search,
                   serve_latency, warm_start)
    return {
        "scheduler": lambda: schedule_search.main(),
        "hrelation": lambda: hrelation.main(),
        "messages": lambda: messages.main(),
        "allreduce": lambda: allreduce.main(
            log_ns=(16, 18) if fast else (18, 20, 22)),
        "fft": lambda: fft.main(max_log2=14 if fast else 18),
        "pagerank": lambda: pagerank.main(
            sizes=((1 << 10, 6),) if fast
            else ((1 << 12, 6), (1 << 14, 6))),
        "roofline": lambda: roofline.main(),
        "overlap": lambda: program_replay.main(compiled=False),
        "compiled_replay": lambda: program_replay.compiled_replay_main(),
        "warm_start": lambda: warm_start.main(),
        "serve": lambda: serve_latency.main(
            n_requests=40 if fast else 120),
    }


def _meta():
    import jax
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "unix_time": time.time(),
    }


def _jsonable(obj):
    try:
        json.dumps(obj)
        return obj
    except (TypeError, ValueError):
        return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="bench-results",
                    default=None, metavar="DIR",
                    help="write BENCH_<name>.json files into DIR")
    args = ap.parse_args()

    meta = _meta()
    out_dir = args.json
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)

    summary = {"meta": meta, "benchmarks": {}}
    failed = []
    for name, job in _jobs(args.fast).items():
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====")
        buf = io.StringIO()
        t0 = time.perf_counter()
        ok, rows, err = True, None, None
        try:
            with contextlib.redirect_stdout(buf):
                rows = _jsonable(job())
        except Exception:                      # report, keep going
            ok = False
            err = traceback.format_exc()
            failed.append(name)
        dt = time.perf_counter() - t0
        stdout = buf.getvalue()
        sys.stdout.write(stdout)
        if err:
            sys.stderr.write(err)
        record = {"name": name, "ok": ok, "seconds": dt, "rows": rows,
                  "stdout": stdout, "error": err, "meta": meta}
        summary["benchmarks"][name] = {"ok": ok, "seconds": dt}
        if out_dir:
            path = os.path.join(out_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(record, f, indent=2)
            print(f"# wrote {path}")
    if out_dir:
        with open(os.path.join(out_dir, "BENCH_summary.json"), "w") as f:
            json.dump(summary, f, indent=2)
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
