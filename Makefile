.PHONY: test test-fast test-slow

# tier-1: the canonical verification command
test:
	scripts/test.sh tier1

# pure planner/unit tests — no XLA compile, runs in seconds
test-fast:
	scripts/test.sh fast

# XLA-compiling SPMD tests
test-slow:
	scripts/test.sh slow
