"""Immortal FFT in use: distributed spectral filtering.

A noisy multi-tone signal is transformed with the LPF BSP FFT (paper
§4.2, Inda–Bisseling), low-pass filtered in the frequency domain, and
transformed back — all on 8 SPMD processes with one total exchange per
transform.  The ledger shows the exact h-relation the immortal analysis
promises: (n/p)(p-1)/p elements per process per exchange.

Run:  PYTHONPATH=src python examples/fft_spectral.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import bsp_fft, fft_h_bytes
from repro.core import probe
from repro.core import compat

N = 1 << 14
CUTOFF = 200


def main():
    mesh = compat.make_mesh((8,), ("x",))
    rng = np.random.default_rng(0)
    t = np.arange(N) / N
    clean = (np.sin(2 * np.pi * 50 * t) + 0.5 * np.sin(2 * np.pi * 120 * t))
    noisy = clean + 0.8 * rng.standard_normal(N)

    spectrum, ledger = bsp_fft(mesh, jnp.asarray(noisy, jnp.complex64),
                               return_ledger=True)
    keep = np.zeros(N)
    keep[:CUTOFF] = 1.0
    keep[-CUTOFF:] = 1.0
    filtered = bsp_fft(mesh, spectrum * jnp.asarray(keep), inverse=True)
    recovered = np.real(np.asarray(filtered))

    err_before = np.sqrt(np.mean((noisy - clean) ** 2))
    err_after = np.sqrt(np.mean((recovered - clean) ** 2))
    print(f"n = {N}, p = 8")
    print(f"RMS error before filtering: {err_before:.3f}")
    print(f"RMS error after filtering:  {err_after:.3f}")
    assert err_after < err_before / 2

    print(f"\npredicted immortal h-relation: {fft_h_bytes(N, 8)} bytes")
    print(f"ledger h-relation:             {ledger.h_bytes} bytes")
    print(ledger.report(probe({"x": 8})))


if __name__ == "__main__":
    main()
