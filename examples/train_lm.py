"""End-to-end training driver: a ~small LM for a few hundred steps on an
emulated (data, model) mesh, with FSDP+TP sharding, checkpointing,
straggler monitoring, and (optionally) LPF cross-pod gradient sync.

Run:  PYTHONPATH=src python examples/train_lm.py            (quick)
      PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticStream
    from repro.launch.mesh import make_mesh
    from repro.optim import AdamWConfig, warmup_cosine
    from repro.runtime.train_loop import TrainLoopConfig, train_loop
    from repro.runtime.train_step import build_train_step

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("llama3.2-1b", smoke=True)   # same family, reduced
    ts = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(
        lr=warmup_cosine(3e-3, 20, args.steps)))
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=64,
                                        global_batch=16, seed=0), cfg)

    def on_step(step, loss, verdict):
        if step % 20 == 0:
            print(f"step {step:>4}  loss {loss:.4f}  "
                  f"{verdict.duration * 1e3:6.1f} ms")

    out = train_loop(ts, stream, TrainLoopConfig(
        steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=50),
        on_step=on_step)
    losses = out["losses"]
    print(f"\nloss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    print(f"checkpoints in {args.ckpt}: restart me to resume from there.")


if __name__ == "__main__":
    main()
