"""Quickstart — the paper's Algorithm 2 ('hello world'), LPF-on-JAX.

Launch an SPMD function on 8 emulated processes, bootstrap a parallel
matrix computation: broadcast the global size from process 0 (via
lpf_get), validate locally, and broadcast errors with CRCW write-conflict
resolution (no extra buffer, exactly as the paper shows).

Run:  PYTHONPATH=src python examples/quickstart.py 1024 512
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import core as lpf
from repro.core import compat

OK, ILLEGAL_INPUT = 0, 1


def spmd(ctx, s, p, args):
    # allocate and activate LPF buffers (lpf_resize_* + sync)
    ctx.resize_memory_register(3)
    ctx.resize_message_queue(p * p + p)

    # register memory areas for communication
    lerr = ctx.register_local("lerr", jnp.zeros(1, jnp.int32))
    gerr = ctx.register_global("gerr", jnp.zeros(1, jnp.int32))
    mdim = ctx.register_global("mdim", args["mdim"])

    # everyone reads the matrix size from the root process
    ctx.get(mdim, mdim, frm=0, size=2)
    ctx.sync(label="fetch-dims")

    dims = ctx.tensor(mdim)
    M = (dims[0] + p - ctx.pid - 1) // p          # my row count
    N = dims[1]
    bad = jnp.where((M <= 0) | (N <= 0), ILLEGAL_INPUT, OK)
    ctx.write(lerr, bad[None].astype(jnp.int32))

    # broadcast errors via CRCW conflict resolution: every process puts
    # its local error to everyone; any nonzero writer wins over zeros
    # (per-pid deterministic order), no gather buffer needed
    for k in range(p):
        ctx.put(lerr, gerr, to=k, size=1,
                where=lambda s_: True)
    ctx.sync(label="error-broadcast")

    err = ctx.tensor(gerr)[0]
    # ... build the local matrix, compute, etc.
    return err, M[None].astype(jnp.int32)


def main():
    m = int(sys.argv[1]) if len(sys.argv) > 1 else 1024
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 512
    mesh = compat.make_mesh((8,), ("x",))
    args = {"mdim": jnp.asarray([m, n], jnp.int32)}
    (err, rows), ledger = lpf.exec_(
        mesh, spmd, args, out_specs=(P(), P("x")), return_ledger=True)
    print(f"global error code: {int(err)} "
          f"({'OK' if int(err) == OK else 'ILLEGAL_INPUT'})")
    print(f"rows per process:  {list(map(int, rows))}")
    print("\nsuperstep ledger (predicted costs on TPU v5e constants):")
    print(ledger.report(lpf.probe({"x": 8})))


if __name__ == "__main__":
    main()
