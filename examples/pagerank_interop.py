"""Interoperability (paper §4.3 / Algorithm 3): an LPF immortal algorithm
called from a FOREIGN parallel program, unmodified on both sides.

The 'host' here is an arbitrary shard_map analytics program (playing
Spark's role).  It hooks the LPF PageRank mid-computation — the paper's
two-step recipe: (1) the host environment already exists, (2) lpf_hook.
No change to the PageRank, no change to the host.

Run:  PYTHONPATH=src python examples/pagerank_interop.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import core as lpf
from repro.algorithms import (partition_graph, reference_pagerank,
                              rmat_graph)
from repro.algorithms.pagerank import pagerank_spmd
from repro.core import compat

N, EDGES, PROCS = 256, 1500, 8


def main():
    mesh = compat.make_mesh((PROCS,), ("x",))
    edges = rmat_graph(N, EDGES, seed=42)
    g = partition_graph(edges, N, PROCS)
    shard = {
        "row_ids": jnp.asarray(g.row_ids), "col_ext": jnp.asarray(g.col_ext),
        "vals": jnp.asarray(g.vals), "pack_idx": jnp.asarray(g.pack_idx),
        "dangling": jnp.asarray(g.dangling),
    }

    def host_analytics(args):
        """A 'Spark stage': local degree statistics... then PageRank."""
        local_nnz = jnp.sum((args["vals"] > 0).astype(jnp.int32))

        def spmd(ctx, s, p, a):          # the unmodified LPF algorithm
            local = {k: v.reshape(v.shape[1:]) for k, v in a.items()}
            return pagerank_spmd(ctx, g, local, tol=1e-7, max_iter=150)

        r, iters, res = lpf.hook(("x",), spmd, args)   # <-- lpf_hook
        return r, iters[None], local_nnz[None]

    fn = jax.jit(compat.shard_map(
        host_analytics, mesh=mesh,
        in_specs=({k: P("x") for k in shard},),
        out_specs=(P("x"), P(), P("x")), check_vma=False))
    r, iters, nnz = fn(shard)

    ref, ref_iters = reference_pagerank(edges, N)
    r = np.asarray(r).reshape(-1)
    err = np.abs(r - ref).max() / ref.max()
    print(f"graph: n={N}, nnz={edges.shape[0]} "
          f"(per-process: {list(map(int, nnz))})")
    print(f"LPF PageRank: {int(iters[0])} iterations to eps=1e-7, "
          f"rel err vs dense oracle {err:.2e}")
    print(f"rank mass: {r.sum():.6f} (dangling handled, sums to 1)")
    top = np.argsort(-r)[:5]
    print("top-5 vertices:", list(map(int, top)))
    assert err < 1e-3


if __name__ == "__main__":
    main()
